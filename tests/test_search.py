"""Branch-and-bound planner (core/search.py): pruned searches must
return answers IDENTICAL to exhaustive enumeration — same cell, same
tie-break — on every query shape tier-1 exercises, and the bounds they
prune with must be sound on full sweeps.

Deterministic twin of tests/test_monotone_property.py (which fuzzes the
same invariants under hypothesis in CI); everything here runs without
optional dependencies.
"""

import pytest

np = pytest.importorskip("numpy")

from repro.configs import ShapeConfig  # noqa: E402
from repro.core import planner as PL  # noqa: E402
from repro.core import search as SR  # noqa: E402
from repro.core import sweep as SW  # noqa: E402
from repro.core.spec import FULL_TRAIN  # noqa: E402


@pytest.fixture(scope="module")
def eng():
    return SW.SweepEngine()


# ---------------------------------------------------------------------------
# statics floor soundness
# ---------------------------------------------------------------------------


FLOOR_GRIDS = [
    dict(arch="llama3.2-3b", kind="train",
         optimizers=("adamw", "adafactor", "adamw8bit"),
         offload_optimizer=(False, True)),
    dict(arch="llama3.1-8b", kind="train"),
    dict(arch="deepseek-v2-lite-16b", kind="train"),
    dict(arch="llava15-7b", kind="train"),
    dict(arch="llama3.2-3b", kind="decode"),
]


@pytest.mark.parametrize("kw", FLOOR_GRIDS,
                         ids=[f"{g['arch']}-{g['kind']}"
                              for g in FLOOR_GRIDS])
def test_floor_never_exceeds_any_peak(eng, kw):
    """floor // n_chips <= peak for EVERY cell of a full sweep — the
    invariant min_chips_search/frontier_search prune with."""
    grid = SW.SweepGrid(chips=(8, 16), chip="v5e",
                        global_batches=(8, 16), seq_lens=(2048,),
                        microbatches=(1, 2), **kw)
    floor = SR._floor_for(grid)
    assert floor > 0
    res = eng.sweep(grid)
    assert len(res) > 0
    bound = floor // res.columns.n_chips
    assert int((res.columns.peak_bytes < bound).sum()) == 0


def test_floor_grows_with_train_statics():
    """The train floor strictly dominates params-only (grads + opt
    states are counted), serve kinds fall back to params, and the
    offload-capable grid drops the optimizer share."""
    params_only = SR.static_floor_bytes("llama3.1-8b", FULL_TRAIN,
                                        kind="decode")
    no_opt = SR.static_floor_bytes("llama3.1-8b", FULL_TRAIN,
                                   kind="train", include_opt=False)
    full = SR.static_floor_bytes("llama3.1-8b", FULL_TRAIN, kind="train")
    assert params_only < no_opt < full
    # adafactor keeps no fp32 master/moments per element -> smaller floor
    ada = SR.static_floor_bytes("llama3.1-8b", FULL_TRAIN, kind="train",
                                optimizer="adafactor")
    assert ada < full


def test_floor_disabled_under_profile():
    from repro.calibrate.profile import CalibrationProfile

    prof = CalibrationProfile(
        coefficients={"static": 0.5, "act_saved": 1.0,
                      "act_transient": 1.0, "overhead": 1.0},
        chip_constant_bytes={})
    grid = SW.SweepGrid(arch="llama3.1-8b", chips=(8,), chip="v5e",
                        global_batches=(8,), seq_lens=(2048,),
                        profile=prof)
    assert SR._floor_for(grid) == 0


# ---------------------------------------------------------------------------
# min-chips / frontier: pruned == exhaustive (oracle-checked)
# ---------------------------------------------------------------------------


MIN_CHIPS_QUERIES = [
    ("llama3.2-3b", ShapeConfig("q", 2048, 16, "train"),
     (4, 8, 16), {}),
    ("llama3.1-8b", ShapeConfig("q", 4096, 16, "train"),
     (8, 16, 32), {}),
    ("deepseek-v2-lite-16b", ShapeConfig("q", 2048, 16, "train"),
     (8, 16, 32), {"allow_ep": True, "max_ep": 4}),
    ("qwen3-32b", ShapeConfig("q", 4096, 32, "train"),
     (8, 16, 32), {"allow_cp": True, "max_cp": 4}),
    ("llama3.2-3b", ShapeConfig("q", 2048, 64, "decode"),
     (4, 8), {"allow_pp": False}),
    # statics floor above every budget: both sides must agree on None
    ("llama3.1-8b", ShapeConfig("q", 2048, 8, "train"),
     (4,), {}),
]


@pytest.mark.parametrize("arch,shape,chips,kw", MIN_CHIPS_QUERIES,
                         ids=[q[0] + "-" + q[1].kind
                              for q in MIN_CHIPS_QUERIES])
def test_min_chips_pruned_equals_exhaustive(eng, arch, shape, chips, kw):
    st = SR.SearchStats()
    got = PL.plan_min_chips(arch, shape, chips=chips, engine=eng,
                            stats=st, **kw)
    ref = PL.plan_min_chips(arch, shape, chips=chips, engine=eng,
                            search="exhaustive", **kw)
    SR._assert_same_cell(got, ref, "min_chips")  # raises on divergence
    # accounting: evaluated + pruned covers exactly the knob domain
    grid = PL._search_grid(arch, shape, chips, "v5e", FULL_TRAIN, "tpu",
                           PL.HEADROOM, kw.get("allow_pp", True), 8,
                           kw.get("allow_ep", False),
                           kw.get("max_ep", 8),
                           kw.get("allow_cp", False),
                           kw.get("max_cp", 8),
                           (1, 4, 8), ("1f1b", "gpipe"), None)
    if grid is not None:
        assert st.total_cells == grid.size()
        assert st.cells_evaluated < grid.size()  # something was pruned


def test_min_chips_search_oracle_mode(eng):
    """oracle=True runs the exhaustive reduction inline and asserts —
    the cross-check the bench and CI lean on."""
    shape = ShapeConfig("q", 2048, 16, "train")
    grid = PL._search_grid("llama3.2-3b", shape, (4, 8, 16), "v5e",
                           FULL_TRAIN, "tpu", PL.HEADROOM, True, 8,
                           False, 8, False, 8, (1, 4, 8),
                           ("1f1b", "gpipe"), None)
    got = SR.min_chips_search(grid, engine=eng, oracle=True)
    assert got is not None and got.fits


FRONTIER_QUERIES = [
    ("llama3.2-3b", ShapeConfig("q", 2048, 64, "train"), (4, 8, 16), {}),
    ("llava15-7b", ShapeConfig("q", 2048, 128, "train"), (8, 16, 32), {}),
    ("deepseek-v2-lite-16b", ShapeConfig("q", 2048, 32, "train"),
     (16, 32), {"allow_ep": True, "max_ep": 4}),
]


@pytest.mark.parametrize("arch,shape,chips,kw", FRONTIER_QUERIES,
                         ids=[q[0] for q in FRONTIER_QUERIES])
def test_frontier_pruned_equals_exhaustive(eng, arch, shape, chips, kw):
    st = SR.SearchStats()
    got = PL.plan_frontier(arch, shape, chips=chips, engine=eng,
                           stats=st, **kw)
    ref = PL.plan_frontier(arch, shape, chips=chips, engine=eng,
                           search="exhaustive", **kw)
    assert got == ref
    assert st.cells_evaluated + st.cells_pruned == st.total_cells


def test_unknown_search_rejected(eng):
    shape = ShapeConfig("q", 2048, 16, "train")
    with pytest.raises(ValueError, match="search"):
        PL.plan_min_chips("llama3.2-3b", shape, chips=(4,), engine=eng,
                          search="greedy")
    with pytest.raises(ValueError, match="search"):
        PL.plan_frontier("llama3.2-3b", shape, chips=(4,), engine=eng,
                         search="greedy")


def test_pruned_equals_exhaustive_under_profile(eng):
    """Calibrated grids disable the floor (0) but must stay exact."""
    from repro.calibrate.profile import CalibrationProfile

    prof = CalibrationProfile(
        coefficients={"static": 0.8, "act_saved": 1.1,
                      "act_transient": 1.0, "overhead": 1.0},
        chip_constant_bytes={"*": 512 * 1024 ** 2})
    shape = ShapeConfig("q", 2048, 16, "train")
    got = PL.plan_min_chips("llama3.2-3b", shape, chips=(4, 8, 16),
                            engine=eng, profile=prof)
    ref = PL.plan_min_chips("llama3.2-3b", shape, chips=(4, 8, 16),
                            engine=eng, profile=prof,
                            search="exhaustive")
    SR._assert_same_cell(got, ref, "min_chips[profile]")


# ---------------------------------------------------------------------------
# aligned-ladder concurrency search
# ---------------------------------------------------------------------------


def test_batch_align():
    assert SR.batch_align({"data": 2, "model": 2, "pipe": 4}) == 4
    assert SR.batch_align({"pipe": 8}) == 1
    assert SR.batch_align({}) == 1
    assert SR.batch_align({"data": 4, "model": 2, "expert": 2}) == 16


CONC_QUERIES = [
    ("llama3.2-3b", 2048, {"data": 1, "model": 4}, "decode", 512),
    ("llama3.2-3b", 2048, {"data": 2, "model": 2}, "decode", 512),
    ("smollm-360m", 1024, {"data": 4, "model": 1}, "decode", 512),
    ("smollm-360m", 512, {"data": 2, "model": 1}, "prefill", 256),
]


@pytest.mark.parametrize("arch,seq,mesh,kind,cap", CONC_QUERIES,
                         ids=[f"{q[0]}-{q[3]}-d{q[2]['data']}"
                              for q in CONC_QUERIES])
def test_max_concurrency_equals_linear_scan(eng, arch, seq, mesh, kind,
                                            cap):
    """The galloping aligned-ladder search vs a full linear scan —
    including data>1 meshes, where peak(gb) is NOT monotone in raw gb
    and a naive binary search over integers would be unsound."""
    budget = int(PL.chip_hbm("v5e") * PL.HEADROOM)

    def peak(gb):
        return eng.report(arch, ShapeConfig("c", seq, gb, kind),
                          dict(mesh), budget_bytes=budget,
                          chip="v5e").peak_bytes

    brute = 0
    for gb in range(1, cap + 1):
        if peak(gb) <= budget:
            brute = gb
    st = SR.SearchStats()
    rep = PL.plan_max_concurrency(arch, seq, mesh_shape=mesh, kind=kind,
                                  cap=cap, engine=eng, stats=st)
    assert rep.max_concurrency == brute
    assert st.probes < cap // 4  # actually pruned, not a hidden scan
    if brute:
        assert rep.peak_bytes == peak(brute) <= budget


def test_max_concurrency_nothing_fits(eng):
    """Even one sequence OOMs on a single v5e for an 8B decode."""
    rep = PL.plan_max_concurrency("llama3.1-8b", 8192,
                                  mesh_shape={"data": 1, "model": 1},
                                  cap=64, engine=eng)
    assert rep.max_concurrency == 0
    assert rep.peak_bytes > rep.budget_bytes


def test_peak_not_monotone_off_ladder(eng):
    """The counterexample motivating the aligned ladder: on a
    batch-sharded mesh there exist gb < gb' with peak(gb) > peak(gb')
    — so monotone_max must NOT binary-search raw integers."""
    budget = int(PL.chip_hbm("v5e") * PL.HEADROOM)
    mesh = {"data": 4, "model": 1}

    def peak(gb):
        return eng.report("smollm-360m", ShapeConfig("c", 1024, gb,
                                                     "decode"),
                          mesh, budget_bytes=budget,
                          chip="v5e").peak_bytes

    vals = [peak(gb) for gb in range(1, 33)]
    assert any(vals[i] > vals[j] for i in range(len(vals))
               for j in range(i + 1, len(vals))), \
        "expected a non-monotone pair on a data-sharded mesh"
    # ...but along the aligned ladder (multiples of 4) it IS monotone
    ladder = vals[3::4]
    assert all(a <= b for a, b in zip(ladder, ladder[1:]))


def test_monotone_max_synthetic_ladders():
    """monotone_max against predicates with known exact answers."""
    for align in (1, 3, 4, 7):
        for true_max in (0, 1, 5, 63, 64, 100):
            def fits(gb, m=true_max):
                return gb <= m
            st = SR.SearchStats()
            got = SR.monotone_max(fits, cap=100, align=align, stats=st)
            assert got == true_max, (align, true_max)
            assert st.probes <= 40


def test_search_stats_merge():
    a = SR.SearchStats(cells_evaluated=3, cells_pruned=7, probes=2)
    b = SR.SearchStats(cells_evaluated=1, cells_pruned=9, probes=0,
                       bound_evals=4)
    a.merge(b)
    assert (a.cells_evaluated, a.cells_pruned, a.probes,
            a.bound_evals) == (4, 16, 2, 4)
    assert a.total_cells == 20
    assert a.reduction == 20 / 6
    assert SR.SearchStats().reduction == float("inf")


# ---------------------------------------------------------------------------
# liveness assembly soundness
# ---------------------------------------------------------------------------


def test_liveness_peak_le_legacy_and_floor_sound(eng):
    """The two invariants that let the branch-and-bound search run
    unchanged under assembly="liveness": every liveness peak is bounded
    above by the legacy peak (sub-sum argument) and below by the
    statics floor (the first event prefix already holds the persistent
    base)."""
    import dataclasses

    live = SW.SweepGrid(arch="llava15-7b", chips=(8, 16), chip="v5e",
                        global_batches=(8, 16), seq_lens=(2048,),
                        microbatches=(1, 2), kind="train",
                        assembly="liveness")
    legacy = dataclasses.replace(live, assembly="legacy")
    r_live = eng.sweep(live)
    r_leg = eng.sweep(legacy)
    assert len(r_live) == len(r_leg) > 0
    lp = r_live.columns.peak_bytes
    gp = r_leg.columns.peak_bytes
    assert (lp <= gp).all()
    assert (lp < gp).any()          # the tighter peak actually bites
    slack = r_live.columns.overlap_slack_bytes
    assert (slack >= 0).all()
    # winning stage's legacy total (live + slack) never exceeds the
    # legacy grid peak (the legacy max is over the same stages)
    assert (lp + slack <= gp).all()
    floor = SR._floor_for(live)
    assert floor > 0
    assert int((lp < floor // r_live.columns.n_chips).sum()) == 0


def test_min_chips_and_frontier_liveness_oracle(eng):
    """Pruned searches vs inline exhaustive oracle, liveness assembly."""
    import dataclasses

    shape = ShapeConfig("q", 2048, 16, "train")
    grid = PL._search_grid("llama3.2-3b", shape, (4, 8, 16), "v5e",
                           FULL_TRAIN, "tpu", PL.HEADROOM, True, 8,
                           False, 8, False, 8, (1, 4, 8),
                           ("1f1b", "gpipe"), None)
    grid = dataclasses.replace(grid, assembly="liveness")
    got = SR.min_chips_search(grid, engine=eng, oracle=True)
    assert got is not None and got.fits
    assert SR.frontier_search(grid, engine=eng, oracle=True)


def test_max_concurrency_liveness_ladder(eng):
    """The aligned batch ladder stays exact under the liveness peak
    (max of gb-aligned-monotone prefixes is monotone): galloping search
    vs a full linear scan on a batch-sharded mesh."""
    budget = int(PL.chip_hbm("v5e") * PL.HEADROOM)
    mesh = {"data": 2, "model": 2}

    def peak(gb):
        return eng.report("llama3.2-3b", ShapeConfig("c", 2048, gb,
                                                     "decode"),
                          dict(mesh), budget_bytes=budget, chip="v5e",
                          assembly="liveness").peak_bytes

    cap = 256
    brute = 0
    for gb in range(1, cap + 1):
        if peak(gb) <= budget:
            brute = gb
    st = SR.SearchStats()
    got = SR.max_concurrency_search(peak, budget, cap, mesh_shape=mesh,
                                    stats=st)
    assert got == brute
    assert st.probes < cap // 4
