"""Serving-fleet memory model (serve/pool.py, serve/fleet.py, the serve
TermSpec seams in core/predictor.py + core/batch.py, and the planner
fleet queries).

Covers the ISSUE-6 test checklist: exact pool-ledger math (conservation,
block alignment), ServeSpec/check_serve validation negative paths, the
neutral-knob bit-parity guarantee (all-neutral serve == no serve at
all), plan_max_concurrency / plan_replicas over the full decode-capable
zoo, a columnar/scalar byte-parity grid including speculative-decode
drafts, and the serve-column report writers.  The hypothesis twin over
random serve specs lives in tests/test_serve_property.py.
"""

import pytest

from repro.configs import ShapeConfig, get_config, registered_archs
from repro.core import planner as PL
from repro.core import sweep as SW
from repro.serve.fleet import BP, RequestMix, expected_len, parse_mix
from repro.serve.pool import (PAGE_TOKENS, ServeSpec, pool_accounting,
                              pool_blocks, pool_tokens)

GiB = 1 << 30

# a mid-size serve spec with every knob active (the canonical test cell)
FULL_SPEC = ServeSpec.make(block_size=16, utilization=0.9,
                           prefix_hit_rate=0.5, prefix_len=256,
                           mix=RequestMix.make(0.25, ((512, 1), (2048, 3))))


# ---------------------------------------------------------------------------
# request-mix math (serve/fleet.py)
# ---------------------------------------------------------------------------


def test_expected_len_identity():
    assert expected_len(4096, None) == 4096
    assert expected_len(4096, RequestMix()) == 4096
    assert RequestMix().is_identity
    assert not RequestMix.make(0.3).is_identity


def test_expected_len_histogram_mean_capped_at_seq_len():
    # plain decode-only histogram: exact floor mean of the capped lengths
    mix = RequestMix.make(0.0, ((512, 1), (2048, 3)))
    assert expected_len(4096, mix) == (512 + 3 * 2048) // 4
    # lengths above the cell's KV capacity are clamped to seq_len
    assert expected_len(1024, mix) == (512 + 3 * 1024) // 4


def test_expected_len_prefill_midpoint():
    # pure prefill phase is charged the chunked-prefill midpoint len//2
    assert expected_len(4096, RequestMix.make(1.0)) == 4096 // 2
    # 50/50 mix: (len + len//2) / 2
    assert expected_len(4096, RequestMix.make(0.5)) == (4096 + 2048) // 2
    # never below one live token
    assert expected_len(1, RequestMix.make(1.0)) == 1


def test_request_mix_validation():
    with pytest.raises(ValueError, match="outside"):
        RequestMix(prefill_bp=BP + 1)
    with pytest.raises(ValueError, match="positive length"):
        RequestMix(hist=((0, 1),))
    with pytest.raises(ValueError, match="positive length"):
        RequestMix(hist=((512, 0),))


def test_parse_mix_syntax():
    assert parse_mix("") is None
    assert parse_mix("0") is None                    # identity -> None
    mix = parse_mix("0.25:512x1,2048x3")
    assert mix == RequestMix.make(0.25, ((512, 1), (2048, 3)))
    assert parse_mix("0.3") == RequestMix.make(0.3)
    with pytest.raises(ValueError, match="not a number"):
        parse_mix("lots:512x1")
    with pytest.raises(ValueError, match="LENxWEIGHT"):
        parse_mix("0.3:512")


# ---------------------------------------------------------------------------
# block-pool ledger (serve/pool.py)
# ---------------------------------------------------------------------------

LEDGER_SPECS = (
    ServeSpec(),                                       # neutral
    ServeSpec.make(block_size=16),
    ServeSpec.make(block_size=16, utilization=0.9),
    ServeSpec.make(utilization=0.7),                   # contiguous
    ServeSpec.make(block_size=32, prefix_hit_rate=1.0, prefix_len=512),
    FULL_SPEC,
)


@pytest.mark.parametrize("spec", LEDGER_SPECS)
@pytest.mark.parametrize("seq_len", (1, 17, 1024, 4096))
def test_pool_ledger_conservation(spec, seq_len):
    acc = pool_accounting(seq_len, spec)
    # conservation: every pool token is live-unique, padding, or frag
    assert acc.pool_tokens == acc.unique + acc.pad_slack + acc.frag_slack
    assert acc.alloc_tokens == acc.unique + acc.pad_slack
    assert acc.pad_slack >= 0 and acc.frag_slack >= 0
    assert 0 <= acc.shared <= acc.live
    assert acc.unique == acc.live - spec.hit_bp * acc.shared // BP
    if spec.block_size:
        # a block allocator hands out whole blocks only
        assert acc.alloc_tokens == acc.blocks * spec.block_size
        assert acc.pool_tokens % spec.block_size == 0
        assert acc.pool_tokens >= acc.alloc_tokens
    else:
        assert acc.blocks == 0 and acc.alloc_tokens == acc.unique


def test_pool_tokens_neutral_degenerates_to_seq_len():
    assert pool_tokens(4096, None) == 4096
    assert pool_tokens(4096, ServeSpec()) == 4096
    assert pool_blocks(4096, None) == 0


def test_pool_exact_when_fully_utilized_contiguous():
    # util=1 + block=0 is exactly the contiguous KV byte count
    mix = RequestMix.make(0.5, ((1024, 1),))
    spec = ServeSpec.make(mix=mix)
    assert pool_tokens(4096, spec) == expected_len(4096, mix)


def test_pool_hit_rate_discounts_shared_prefix():
    base = ServeSpec.make(block_size=16)
    hit = ServeSpec.make(block_size=16, prefix_hit_rate=0.5,
                         prefix_len=256)
    full = ServeSpec.make(block_size=16, prefix_hit_rate=1.0,
                          prefix_len=256)
    assert pool_tokens(1024, hit) < pool_tokens(1024, base)
    # a guaranteed hit removes the whole shared prefix
    acc = pool_accounting(1024, full)
    assert acc.unique == 1024 - 256
    # prefix longer than the context: sharing caps at the live length
    capped = pool_accounting(100, full)
    assert capped.shared == 100 and capped.unique == 0


def test_pool_utilization_inflates_in_whole_blocks():
    spec = ServeSpec.make(block_size=16, utilization=0.9)
    acc = pool_accounting(1024, spec)
    assert acc.blocks == 64
    assert acc.pool_tokens == -(-64 * BP // spec.util_bp) * 16  # 72 blocks
    # contiguous inflation is token-granular
    acc2 = pool_accounting(1024, ServeSpec.make(utilization=0.9))
    assert acc2.pool_tokens == -(-1024 * BP // 9000)


def test_serve_spec_validation():
    with pytest.raises(ValueError, match="page-aligned"):
        ServeSpec(block_size=12)                 # not a multiple of 8
    with pytest.raises(ValueError, match="page-aligned"):
        ServeSpec(block_size=-8)
    with pytest.raises(ValueError, match="utilization"):
        ServeSpec(util_bp=0)
    with pytest.raises(ValueError, match="utilization"):
        ServeSpec.make(utilization=1.5)
    with pytest.raises(ValueError, match="hit rate"):
        ServeSpec.make(prefix_hit_rate=-0.1)
    with pytest.raises(ValueError, match="negative"):
        ServeSpec(prefix_len=-1)
    with pytest.raises(ValueError, match="prefix-len"):
        ServeSpec.make(prefix_hit_rate=0.5)      # hit without a prefix
    assert ServeSpec(block_size=PAGE_TOKENS).block_size == 8


def test_serve_spec_neutrality():
    assert ServeSpec().is_neutral
    assert ServeSpec.make(mix=RequestMix()).is_neutral
    for spec in (ServeSpec.make(block_size=16),
                 ServeSpec.make(utilization=0.9),
                 ServeSpec.make(prefix_hit_rate=0.1, prefix_len=1),
                 ServeSpec.make(mix=RequestMix.make(0.3)),
                 ServeSpec.make(draft_arch="smollm-360m")):
        assert not spec.is_neutral


# ---------------------------------------------------------------------------
# check_serve / make_context validation gate
# ---------------------------------------------------------------------------


def test_check_serve_rejects_serve_on_train():
    cfg = get_config("smollm-360m")
    with pytest.raises(ValueError, match="train"):
        PL.check_serve(cfg, ServeSpec.make(block_size=16), "train")
    # neutral specs pass everywhere (they are normalized away)
    PL.check_serve(cfg, ServeSpec(), "train")
    PL.check_serve(cfg, None, "train")


def test_check_serve_rejects_draft_off_decode():
    cfg = get_config("smollm-360m")
    with pytest.raises(ValueError, match="decode"):
        PL.check_serve(cfg, ServeSpec.make(draft_arch="smollm-360m"),
                       "prefill")


def test_check_serve_rejects_unknown_draft():
    cfg = get_config("llama3.2-3b")
    with pytest.raises(ValueError, match="unknown draft arch"):
        PL.check_serve(cfg, ServeSpec.make(draft_arch="gpt17"), "decode")


def test_make_context_normalizes_neutral_serve():
    cfg = get_config("smollm-360m")
    ctx = PL.make_context(cfg, {"data": 2}, kind="decode",
                          global_batch=8, seq_len=1024,
                          serve=ServeSpec())
    assert ctx.serve is None
    ctx2 = PL.make_context(cfg, {"data": 2}, kind="decode",
                           global_batch=8, seq_len=1024, serve=FULL_SPEC)
    assert ctx2.serve == FULL_SPEC


def test_neutral_serve_bit_identical_to_no_serve(sweep_engine):
    shape = ShapeConfig("t", 2048, 8, "decode")
    base = sweep_engine.report("llama3.2-3b", shape, {"data": 2},
                               budget_bytes=16 * GiB)
    neut = sweep_engine.report("llama3.2-3b", shape, {"data": 2},
                               budget_bytes=16 * GiB, serve=ServeSpec())
    assert neut.prediction is base.prediction    # same memo key
    assert neut.peak_bytes == base.peak_bytes
    assert neut.prediction.pool_bytes == 0
    assert neut.prediction.draft_bytes == 0
    assert neut.prediction.hit_saved_bytes == 0


def test_paged_serve_changes_only_serve_components(sweep_engine):
    shape = ShapeConfig("t", 2048, 8, "decode")
    base = sweep_engine.report("llama3.2-3b", shape, {"data": 2},
                               budget_bytes=16 * GiB).prediction
    srv = sweep_engine.report("llama3.2-3b", shape, {"data": 2},
                              budget_bytes=16 * GiB,
                              serve=FULL_SPEC).prediction
    # weights/acts are serving-invariant; only the KV terms move
    assert srv.param_bytes == base.param_bytes
    assert srv.act_saved_bytes == base.act_saved_bytes
    assert srv.pool_bytes > 0
    assert srv.hit_saved_bytes >= 0


# ---------------------------------------------------------------------------
# planner fleet queries (ROADMAP questions 1 + 2)
# ---------------------------------------------------------------------------

# smallest {"data": 1, "model": N} replica mesh that serves each zoo
# arch at 2048 tokens on one v5e (from the probe in the PR notes)
REPLICA_MODEL_DEGREE = {
    "arctic-480b": 64,
    "deepseek-v2-lite-16b": 4,
    "llama3.1-8b": 4,
    "llama3.2-3b": 1,
    "llava-next-mistral-7b": 1,
    "llava15-7b": 1,
    "mamba2-1.3b": 1,
    "minicpm3-4b": 1,
    "qwen3-32b": 16,
    "seamless-m4t-large-v2": 1,
    "smollm-360m": 1,
    "zamba2-2.7b": 1,
}


def test_replica_mesh_map_covers_the_zoo():
    assert set(REPLICA_MODEL_DEGREE) == set(registered_archs())


@pytest.mark.parametrize("arch", sorted(REPLICA_MODEL_DEGREE))
def test_plan_max_concurrency_all_decode_arches(arch, sweep_engine):
    mesh = {"data": 1, "model": REPLICA_MODEL_DEGREE[arch]}
    rep = PL.plan_max_concurrency(arch, 2048, mesh_shape=mesh,
                                  engine=sweep_engine)
    assert rep.max_concurrency >= 1
    assert rep.peak_bytes <= rep.budget_bytes
    assert rep.kind == "decode" and rep.seq_len == 2048


def test_plan_max_concurrency_is_maximal(sweep_engine):
    rep = PL.plan_max_concurrency("llama3.2-3b", 2048, engine=sweep_engine)
    shape = ShapeConfig("t", 2048, rep.max_concurrency + 1, "decode")
    over = sweep_engine.report("llama3.2-3b", shape, rep.mesh_shape,
                               budget_bytes=rep.budget_bytes)
    assert over.peak_bytes > rep.budget_bytes    # one more seq OOMs


def test_plan_max_concurrency_zero_when_nothing_fits(sweep_engine):
    rep = PL.plan_max_concurrency("arctic-480b", 2048,
                                  mesh_shape={"data": 1, "model": 1},
                                  engine=sweep_engine)
    assert rep.max_concurrency == 0
    assert rep.peak_bytes > rep.budget_bytes


def test_prefix_hits_never_reduce_concurrency(sweep_engine):
    base = PL.plan_max_concurrency("llama3.2-3b", 2048,
                                   engine=sweep_engine)
    hit = PL.plan_max_concurrency(
        "llama3.2-3b", 2048, engine=sweep_engine,
        serve=ServeSpec.make(prefix_hit_rate=0.9, prefix_len=1024))
    assert hit.max_concurrency >= base.max_concurrency


def test_plan_replicas_consistent_with_concurrency(sweep_engine):
    fleet = PL.plan_replicas("llama3.2-3b", qps=20, seq_len=2048,
                             latency_s=10.0, engine=sweep_engine)
    assert fleet.concurrent_requests == 200        # Little's law
    per = fleet.per_replica
    assert fleet.replicas == -(-fleet.concurrent_requests // per)
    assert fleet.total_chips == fleet.replicas * fleet.chips_per_replica
    assert "replicas" in str(fleet)


def test_plan_replicas_validation(sweep_engine):
    with pytest.raises(ValueError, match="positive"):
        PL.plan_replicas("smollm-360m", qps=0, seq_len=1024,
                         engine=sweep_engine)
    with pytest.raises(ValueError, match="bigger mesh"):
        PL.plan_replicas("arctic-480b", qps=1, seq_len=2048,
                         mesh_shape={"data": 1, "model": 1},
                         engine=sweep_engine)


# ---------------------------------------------------------------------------
# columnar/scalar byte-parity on a serve grid (incl. a draft model)
# ---------------------------------------------------------------------------


def _serve_grid(**kw):
    base = dict(arch="smollm-360m", kind="decode",
                mesh_shapes=({"data": 2}, {"data": 1, "model": 2}),
                global_batches=(8,), seq_lens=(1024,),
                block_sizes=(0, 16), utilizations=(1.0, 0.85),
                prefix_hit_rates=(0.0, 0.5), prefix_len=128,
                mixes=(None, RequestMix.make(0.25, ((512, 1), (2048, 3)))),
                draft_archs=("", "smollm-360m"))
    base.update(kw)
    return SW.SweepGrid(**base)


def _cell_key(r):
    return (r.arch, tuple(sorted(r.mesh_shape.items())), r.global_batch,
            r.seq_len, r.grad_accum, r.serve)


def test_columnar_scalar_parity_on_serve_grid(sweep_engine):
    grid = _serve_grid()
    col = SW.sweep(grid, engine=sweep_engine, mode="columnar")
    cell = SW.sweep(grid, engine=sweep_engine, mode="cell")
    assert len(col) == len(cell) == grid.size()
    by_key = {_cell_key(r): r for r in cell.results}
    assert len(by_key) == len(cell)
    for r in col.results:
        s = by_key[_cell_key(r)]
        assert (r.peak_bytes, r.pool_bytes, r.draft_bytes,
                r.hit_saved_bytes, r.fits) == \
               (s.peak_bytes, s.pool_bytes, s.draft_bytes,
                s.hit_saved_bytes, s.fits), _cell_key(r)


def test_draft_residency_positive_and_first_stage_only(sweep_engine):
    shape = ShapeConfig("t", 1024, 8, "decode")
    spec = ServeSpec.make(block_size=16, draft_arch="smollm-360m")
    rep = sweep_engine.report("llama3.2-3b", shape, {"data": 2},
                              budget_bytes=32 * GiB, serve=spec)
    nod = sweep_engine.report("llama3.2-3b", shape, {"data": 2},
                              budget_bytes=32 * GiB,
                              serve=ServeSpec.make(block_size=16))
    assert rep.prediction.draft_bytes > 0
    assert rep.peak_bytes == nod.peak_bytes + rep.prediction.draft_bytes


# ---------------------------------------------------------------------------
# serve-column report writers + CLI (satellite: no silently-dropped fields)
# ---------------------------------------------------------------------------


def test_writers_render_serve_columns(sweep_engine):
    grid = _serve_grid(mesh_shapes=({"data": 2},), draft_archs=("",))
    res = SW.sweep(grid, engine=sweep_engine)
    md = res.to_markdown(limit=4)
    for col in ("block", "blocks_per_seq", "hit", "pool_gib",
                "hit_saved_gib", "draft_gib"):
        assert col in md, col
    csv = res.to_csv()
    head = csv.splitlines()[0]
    assert "pool_gib" in head and "draft_gib" in head
    assert len(csv.splitlines()) == len(res) + 1


def test_writers_skip_serve_columns_on_neutral_grid(sweep_engine):
    grid = SW.SweepGrid(arch="smollm-360m", chips=4,
                        global_batches=(16,), seq_lens=(256,))
    res = SW.sweep(grid, engine=sweep_engine)
    assert "pool_gib" not in res.to_markdown(limit=3)
    assert "pool_gib" not in res.to_csv().splitlines()[0]


def test_sweep_cli_serve_smoke(capsys):
    rc = SW.main(["--arch", "smollm_360m", "--mesh", "data=2",
                  "--kind", "decode", "--batch", "8",
                  "--seq-len", "1024", "--block-size", "0,16",
                  "--utilization", "0.9", "--prefix-hit-rate", "0,0.5",
                  "--prefix-len", "128", "--mix", "0.25:512x1,2048x3",
                  "--top", "5"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "pool_gib" in out and "hit_saved_gib" in out


def test_sweep_cli_rejects_serve_on_train(capsys):
    with pytest.raises(SystemExit):
        SW.main(["--arch", "smollm_360m", "--chips", "4",
                 "--kind", "train", "--block-size", "16"])
    assert "train" in capsys.readouterr().err


def test_sweep_cli_rejects_bad_mix(capsys):
    with pytest.raises(SystemExit):
        SW.main(["--arch", "smollm_360m", "--chips", "4",
                 "--kind", "decode", "--mix", "0.3:512"])
    assert "LENxWEIGHT" in capsys.readouterr().err


def test_sweep_cli_rejects_unknown_draft(capsys):
    with pytest.raises(SystemExit):
        SW.main(["--arch", "smollm_360m", "--chips", "4",
                 "--kind", "decode", "--draft-arch", "gpt17"])
    assert "unknown draft arch" in capsys.readouterr().err


def test_breakdown_cli_serve_summary(capsys):
    from repro.configs.__main__ import main as cfg_main
    rc = cfg_main(["--breakdown", "--arch", "llama3_2_3b",
                   "--shape", "decode_32k", "--mesh", "data=1,model=2",
                   "--block-size", "16", "--utilization", "0.9",
                   "--prefix-hit-rate", "0.5", "--prefix-len", "256"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "serving: block 16" in out
    assert "kv_pool" in out and "prefix hits save" in out


def test_breakdown_cli_rejects_serve_on_train_shape():
    from repro.configs.__main__ import main as cfg_main
    with pytest.raises(SystemExit):
        cfg_main(["--breakdown", "--arch", "smollm_360m",
                  "--block-size", "16"])       # default shape is train_4k


def test_breakdown_cli_serve_needs_breakdown():
    from repro.configs.__main__ import main as cfg_main
    with pytest.raises(SystemExit):
        cfg_main(["--block-size", "16"])
