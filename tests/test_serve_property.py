"""Property tests (hypothesis) for the serving-fleet pool model.

Randomized ServeSpec/RequestMix draws assert the paged-pool ledger
invariants — monotonicity in sequence length and concurrency, hit-rate
zero meaning no prefix sharing, full utilization with contiguous
allocation meaning the exact contiguous KV byte count, and block-count
conservation — plus scalar/columnar byte-parity over random serve
grids.  Same importorskip convention as tests/test_batch_property.py:
CI installs hypothesis via requirements-dev.txt and runs the shared
fixed-seed "ci" profile from tests/conftest.py; the deterministic twin
lives in tests/test_serve.py.
"""

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis; `pip install hypothesis` "
           "to run them")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import ShapeConfig  # noqa: E402
from repro.core import sweep as SW  # noqa: E402
from repro.serve.fleet import BP, RequestMix, expected_len  # noqa: E402
from repro.serve.pool import (ServeSpec, pool_accounting,  # noqa: E402
                              pool_tokens)

GiB = 1 << 30

_mixes = st.one_of(
    st.none(),
    st.builds(
        RequestMix,
        prefill_bp=st.integers(0, BP),
        hist=st.lists(
            st.tuples(st.integers(1, 8192), st.integers(1, 5)),
            max_size=3).map(tuple)))

_specs = st.builds(
    ServeSpec,
    block_size=st.sampled_from([0, 8, 16, 32, 128]),
    util_bp=st.integers(1, BP),
    hit_bp=st.integers(0, BP),
    prefix_len=st.integers(1, 4096),   # >0 so any hit_bp is legal
    mix=_mixes)

_seq_lens = st.integers(1, 1 << 20)


@settings(deadline=None)
@given(spec=_specs, seq_len=_seq_lens)
def test_property_pool_ledger_conservation(spec, seq_len):
    acc = pool_accounting(seq_len, spec)
    # allocated = live-unique + last-block padding + fragmentation slack
    assert acc.pool_tokens == acc.unique + acc.pad_slack + acc.frag_slack
    assert acc.pad_slack >= 0 and acc.frag_slack >= 0
    assert 0 <= acc.shared <= acc.live
    if spec.block_size:
        assert acc.alloc_tokens == acc.blocks * spec.block_size
        assert acc.pool_tokens % spec.block_size == 0
    else:
        assert acc.blocks == 0 and acc.alloc_tokens == acc.unique


@settings(deadline=None)
@given(spec=_specs, seq_len=_seq_lens, grow=st.integers(1, 1 << 16))
def test_property_pool_monotone_in_seq_len(spec, seq_len, grow):
    assert pool_tokens(seq_len + grow, spec) >= pool_tokens(seq_len, spec)


@settings(deadline=None)
@given(spec=_specs, seq_len=_seq_lens)
def test_property_hit_zero_means_no_sharing(spec, seq_len):
    nohit = ServeSpec(block_size=spec.block_size, util_bp=spec.util_bp,
                      hit_bp=0, prefix_len=0, mix=spec.mix)
    acc = pool_accounting(seq_len, nohit)
    assert acc.shared == 0
    assert acc.unique == acc.live == expected_len(seq_len, spec.mix)
    # ... and prefix_len alone (without hits) changes nothing
    withlen = ServeSpec(block_size=spec.block_size, util_bp=spec.util_bp,
                        hit_bp=0, prefix_len=spec.prefix_len,
                        mix=spec.mix)
    assert pool_accounting(seq_len, withlen) == acc


@settings(deadline=None)
@given(mix=_mixes, seq_len=_seq_lens)
def test_property_full_util_contiguous_is_exact(mix, seq_len):
    spec = ServeSpec(block_size=0, util_bp=BP, mix=mix)
    acc = pool_accounting(seq_len, spec)
    assert acc.pool_tokens == expected_len(seq_len, mix)
    assert acc.pad_slack == 0 and acc.frag_slack == 0


@settings(max_examples=25, deadline=None)
@given(
    block=st.sampled_from([0, 16, 32]),
    util=st.sampled_from([1.0, 0.9, 0.6]),
    hit=st.sampled_from([0.0, 0.5, 1.0]),
    mix=_mixes,
    draft=st.sampled_from(["", "smollm-360m"]),
    batches=st.lists(st.integers(1, 32), min_size=1, max_size=2,
                     unique=True),
    seqs=st.lists(st.sampled_from([256, 512, 1024, 2048]), min_size=1,
                  max_size=2, unique=True))
def test_property_columnar_equals_cell_on_serve_grids(
        block, util, hit, mix, draft, batches, seqs):
    grid = SW.SweepGrid(
        arch="smollm-360m", kind="decode",
        mesh_shapes=({"data": 2}, {"data": 1, "model": 2}),
        global_batches=tuple(batches), seq_lens=tuple(seqs),
        block_sizes=tuple(dict.fromkeys((0, block))),
        utilizations=(util,),
        prefix_hit_rates=(hit,), prefix_len=256 if hit else 0,
        mixes=(mix,), draft_archs=(draft,))
    cell = SW.SweepEngine().sweep(grid, mode="cell")
    col = SW.SweepEngine().sweep(grid, mode="columnar")
    assert len(cell) == len(col) == grid.size()
    for a, b in zip(cell.results, col.results):
        assert a == b


@settings(max_examples=10, deadline=None)
@given(
    gb=st.integers(1, 64),
    extra=st.integers(1, 64),
    seq=st.sampled_from([512, 1024, 2048]),
    spec=_specs)
def test_property_pool_bytes_monotone_in_concurrency(gb, extra, seq, spec,
                                                     engine):
    # more in-flight sequences can never shrink the KV pool (per-seq
    # pool tokens are concurrency-independent; the pool term is linear
    # in the batch): asserted at the report level on a shard-free mesh
    def pool(n):
        rep = engine.report("smollm-360m",
                            ShapeConfig("t", seq, n, "decode"),
                            {"data": 1, "model": 1},
                            budget_bytes=1 << 62, serve=spec)
        return rep.prediction.pool_bytes

    assert pool(gb + extra) >= pool(gb)


@pytest.fixture(scope="module")
def engine():
    return SW.SweepEngine()
