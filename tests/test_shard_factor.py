"""Packed shard-factor kernels (kernels/shard_factor.py): the jax and
pallas evaluators must reproduce core.batch.batch_shard_factor — the
greedy masked axis assignment — byte for byte on randomized programs
and on real columnar sweeps routed through use_backend().
"""

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core import batch as B  # noqa: E402
from repro.core import sweep as SW  # noqa: E402
from repro.kernels import shard_factor as K  # noqa: E402

RNG = np.random.default_rng(20260808)

MESH_AXES = ("data", "model", "expert", "context", "pipe")
LOGICAL = ("batch", "heads", "dmodel", "seq", "experts", "layers")


def random_program(rng, n_cells):
    """One randomized (dims, axes, sizes, rules, extra) instance with
    the reference's edge cases reachable: pipe in rules (never shards),
    the layers stack dim (excluded from the extra pass), multi-axis
    rules, size-1 (dead) axes, and dims with no rule at all."""
    rules = {}
    for name in LOGICAL:
        k = rng.integers(0, 3)
        rules[name] = tuple(
            rng.choice(MESH_AXES, size=k, replace=False)) if k else ()
    n_dims = int(rng.integers(1, 5))
    axes = tuple(rng.choice(LOGICAL + (None,)) for _ in range(n_dims))
    dims = [rng.choice([1, 2, 3, 4, 6, 8, 12, 16, 24, 64],
                       size=n_cells).astype(np.int64)
            for _ in range(n_dims)]
    sizes = {a: rng.choice([1, 1, 2, 4, 8], size=n_cells).astype(np.int64)
             for a in MESH_AXES}
    extra = tuple(rng.choice(MESH_AXES,
                             size=int(rng.integers(0, 3)),
                             replace=False))
    return dims, axes, sizes, rules, extra


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_randomized_program_parity(backend):
    for trial in range(25):
        dims, axes, sizes, rules, extra = random_program(RNG, n_cells=17)
        ref = B.batch_shard_factor(dims, axes, sizes, rules, extra)
        got = K.shard_factor(dims, axes, sizes, rules, extra,
                             backend=backend)
        assert got.dtype == np.int64
        assert np.array_equal(np.asarray(got), ref), \
            f"trial {trial}: {axes} rules={rules} extra={extra}"


def test_scalar_and_broadcast_inputs():
    """Int dims and mixed scalar/array sizes broadcast like the
    reference."""
    dims = [8, np.array([4, 8, 16], dtype=np.int64)]
    axes = ("batch", "heads")
    rules = {"batch": ("data",), "heads": ("model",)}
    sizes = {"data": 2, "model": np.array([1, 2, 4], dtype=np.int64)}
    ref = B.batch_shard_factor(dims, axes, sizes, rules, ())
    got = K.shard_factor(dims, axes, sizes, rules, (), backend="jax")
    assert np.array_equal(np.asarray(got), ref)


def test_pallas_pads_partial_blocks():
    """Lane counts that don't divide the block are padded with neutral
    cells and trimmed — answers unchanged."""
    dims, axes, sizes, rules, extra = random_program(RNG, n_cells=7)
    ref = B.batch_shard_factor(dims, axes, sizes, rules, extra)
    got = K.shard_factor(dims, axes, sizes, rules, extra,
                         backend="pallas", block=4)
    assert np.array_equal(np.asarray(got), ref)


def test_pack_program_shape():
    steps, names = K.pack_program(
        axes=("batch", "heads"),
        rules={"batch": ("data",), "heads": ("model", "data")},
        extra=("data",), axis_names=("data", "model"))
    assert names and set(names) <= {"data", "model"}
    assert all(len(s) == 3 for s in steps)
    # rules steps for both dims, then the extra pass per dim
    flags = [f for (_, _, f) in steps]
    assert 0 in flags and 2 in flags
    # axes outside axis_names are dropped (the dead-axis filter)
    steps2, names2 = K.pack_program(
        axes=("batch",), rules={"batch": ("data",)}, extra=(),
        axis_names=())
    assert not steps2 and not names2


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="backend"):
        K.shard_factor([4], ("batch",), {"data": 2},
                       {"batch": ("data",)}, (), backend="cuda")


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_use_backend_real_sweep_parity(backend):
    """A real columnar sweep with batch_shard_factor routed through the
    kernel: verdicts and peaks byte-identical to the numpy path."""
    grid = SW.SweepGrid(arch="smollm-360m", chips=(2, 4), chip="v5e",
                        global_batches=(8, 16), seq_lens=(512,),
                        microbatches=(1, 2), kind="train")
    ref = SW.SweepEngine().sweep(grid)
    with K.use_backend(backend):
        got = SW.SweepEngine().sweep(grid)
    assert np.array_equal(got.columns.peak_bytes, ref.columns.peak_bytes)
    assert np.array_equal(got.columns.fits, ref.columns.fits)


def test_use_backend_restores_impl():
    assert B._shard_factor_impl is None
    with K.use_backend("jax"):
        assert B._shard_factor_impl is not None
    assert B._shard_factor_impl is None
    with pytest.raises(RuntimeError):
        with K.use_backend("pallas"):
            assert B._shard_factor_impl is not None
            raise RuntimeError("boom")
    assert B._shard_factor_impl is None
    # numpy is a no-op route
    with K.use_backend("numpy"):
        assert B._shard_factor_impl is None
