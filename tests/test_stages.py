"""Pipeline-parallel memory model: stage partitioner properties, pp=1
byte-parity with the non-pipelined predictor, columnar/cell parity on
pp > 1 grids, and the schedule/boundary helpers.

The partitioner contract (core/stages.py): contiguous stages, exact
cover of every repeat unit, pinned front (embedding / vision tower /
audio encoder) and tail (final norm / LM head), balance bounded by the
greedy guarantee.  The predictor contract: a mesh whose ``pipe`` axis is
1 (or absent) reproduces today's predictions byte-for-byte, whatever the
microbatch/schedule knobs say.
"""

import pytest

from repro.configs import ShapeConfig, get_config, registered_archs
from repro.core import planner
from repro.core import predictor as PR
from repro.core import stages as ST
from repro.core import sweep as SW
from repro.core.parser import parse_model, total_params
from repro.core.spec import FULL_TRAIN, LLAVA_STAGE2
from repro.models import build_model

ARCHS = registered_archs()
PPS = (1, 2, 3, 4, 8)


@pytest.fixture(scope="session")
def rows_of(zoo_rows):
    """Session-cached parse tables (same spec trees the engine memoizes)."""
    def get(arch, policy=FULL_TRAIN):
        return list(zoo_rows(arch, policy)[2])
    return get


# ---------------------------------------------------------------------------
# partitioner properties across the zoo
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCHS)
def test_partition_exact_cover(arch, rows_of):
    """Summing any repeat-linear quantity over stages reproduces the
    whole model — no unit lost, none double-counted."""
    rows = rows_of(arch)
    want = total_params(rows)
    for pp in PPS:
        plan = ST.partition(rows, pp)
        assert len(plan.stages) == pp
        got = sum(total_params(list(s)) for s in plan.stages)
        assert got == want, (arch, pp)


@pytest.mark.parametrize("arch", ARCHS)
def test_partition_contiguity(arch, rows_of):
    """Stages walk the original row order monotonically, and a split
    scan stack's chunk repeats sum to the original depth."""
    rows = rows_of(arch)
    seg_order = {}
    for r in rows:
        seg_order.setdefault(r.module_path, len(seg_order))
    for pp in PPS:
        plan = ST.partition(rows, pp)
        flat = [r for s in plan.stages for r in s]
        # monotone segment order (a split stack restarts its row list on
        # the next stage — same module_path, so the segment id is equal)
        idx = [seg_order[r.module_path] for r in flat]
        assert idx == sorted(idx), (arch, pp)
        # a segment's stages form one contiguous run
        holders: dict = {}
        for si, s in enumerate(plan.stages):
            for r in s:
                holders.setdefault(r.module_path, []).append(si)
        for path, sis in holders.items():
            uniq = sorted(set(sis))
            assert uniq == list(range(uniq[0], uniq[-1] + 1)), \
                (arch, pp, path)
        # per-path repeat conservation
        by_path: dict = {}
        for r in flat:
            by_path[r.path] = by_path.get(r.path, 0) + r.repeat
        for r in rows:
            assert by_path[r.path] == r.repeat, (arch, pp, r.path)


@pytest.mark.parametrize("arch", ARCHS)
def test_partition_balance_bound(arch, rows_of):
    """DP optimum never exceeds the greedy guarantee:
    max(front, tail) + ceil(middle_total/pp) + max_unit."""
    rows = rows_of(arch)
    segs = ST._segments(rows)
    split_ids = [i for i, s in enumerate(segs) if s.splittable]
    if not split_ids:
        pytest.skip("no splittable segments")
    first, last = split_ids[0], split_ids[-1]
    front = sum(s.total_weight() for s in segs[:first])
    tail = sum(s.total_weight() for s in segs[last + 1:])
    units = []
    for seg in segs[first:last + 1]:
        if seg.splittable:
            units.extend([seg.unit_weight()] * seg.repeat)
        else:
            units.append(seg.total_weight())
    for pp in PPS:
        if pp == 1:
            continue                  # one stage holds front+middle+tail
        plan = ST.partition(rows, pp)
        bound = max(front, tail) + -(-sum(units) // pp) + max(units)
        assert max(plan.weights) <= bound, (arch, pp)


def test_partition_pins_embedding_and_head(rows_of):
    rows = rows_of("llama3.1-8b")
    plan = ST.partition(rows, 4)
    stage0_kinds = {r.layer.kind for r in plan.stages[0]}
    assert "embedding" in stage0_kinds
    # final norm (head module) on the last stage only
    last_paths = {r.module_path for r in plan.stages[-1]}
    assert any(p.endswith("head") for p in last_paths)
    for s in plan.stages[:-1]:
        assert not any(r.module_path.endswith("head") for r in s)


@pytest.mark.parametrize("policy", [FULL_TRAIN, LLAVA_STAGE2],
                         ids=["full", "stage2-frozen-tower"])
def test_partition_pins_vision_tower(policy, rows_of):
    """The vision tower (frozen or not) is never split: all its rows ride
    on stage 0."""
    rows = rows_of("llava15-7b", policy)
    for pp in (2, 4):
        plan = ST.partition(rows, pp)
        for si, stage in enumerate(plan.stages):
            for r in stage:
                if r.modality == "vision":
                    assert si == 0, (pp, r.path)
        # and stage-0 keeps the full tower depth
        tower = [r for r in plan.stages[0] if "vision_tower/blocks"
                 in r.path]
        full = [r for r in rows if "vision_tower/blocks" in r.path]
        assert sum(r.repeat for r in tower) == sum(r.repeat for r in full)


def test_partition_pins_audio_encoder(rows_of):
    rows = rows_of("seamless-m4t-large-v2")
    plan = ST.partition(rows, 4)
    for si, stage in enumerate(plan.stages):
        for r in stage:
            if r.modality == "audio":
                assert si == 0, (si, r.path)


def test_partition_atomic_shared_blocks(rows_of):
    """zamba2's weight-tied shared attention (invocation_repeat) is never
    split across stages."""
    rows = rows_of("zamba2-2.7b")
    for pp in (2, 4):
        plan = ST.partition(rows, pp)
        holders = [si for si, s in enumerate(plan.stages)
                   if any("shared_attn" in r.module_path for r in s)]
        assert len(holders) == 1, (pp, holders)


def test_stash_count_schedules():
    # 1F1B: stage i holds min(pp - i, m); GPipe holds all m
    assert [ST.stash_count(i, 4, 8) for i in range(4)] == [4, 3, 2, 1]
    assert [ST.stash_count(i, 4, 2) for i in range(4)] == [2, 2, 2, 1]
    assert [ST.stash_count(i, 4, 8, "gpipe") for i in range(4)] == [8] * 4
    assert ST.stash_count(0, 1, 8) == 1          # no pipeline, no stash
    assert ST.stash_count(0, 1, 8, "gpipe") == 1
    with pytest.raises(ValueError):
        ST.stash_count(0, 4, 8, "interleaved")


def test_boundary_edges():
    assert [ST.boundary_edges(i, 4) for i in range(4)] == [1, 2, 2, 1]
    assert ST.boundary_edges(0, 1) == 0


# ---------------------------------------------------------------------------
# pp=1 byte-parity: the pipeline path degenerates to today's predictions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCHS)
def test_pp1_reproduces_baseline_predictions(arch):
    """A pipe=1 mesh — with whatever microbatch/schedule knobs — is
    byte-for-byte the plain prediction on every registered arch."""
    shape = ShapeConfig("cell", 512, 8, "train")
    base = planner.check(arch, shape, {"data": 2, "model": 2},
                         backend="cpu")
    for m, sched in ((1, "1f1b"), (8, "1f1b"), (8, "gpipe")):
        pp1 = planner.check(arch, shape,
                            {"data": 2, "model": 2, "pipe": 1},
                            backend="cpu", microbatches=m, schedule=sched)
        assert pp1.peak_bytes == base.peak_bytes, (arch, m, sched)
        p, b = pp1.prediction, base.prediction
        for f in ("param_bytes", "grad_bytes", "opt_bytes",
                  "act_saved_bytes", "act_transient_bytes", "loss_bytes",
                  "input_bytes", "cache_bytes", "output_copy_bytes"):
            assert getattr(p, f) == getattr(b, f), (arch, f)


def test_pipe_axis_never_shards_tensors():
    """mesh_ctx skips the pipe axis in the rule pass AND the FSDP/ZeRO
    extra pass, even when a rule table names it."""
    from repro.mesh_ctx import DEFAULT_RULES, shard_factor
    rules = dict(DEFAULT_RULES)
    base = shard_factor((64, 4096), ("batch", None), {"data": 4},
                        rules, ("data",))
    with_pipe = shard_factor((64, 4096), ("batch", None),
                             {"data": 4, "pipe": 4}, rules, ("data",))
    assert with_pipe == base
    rules["batch"] = ("pipe", "data")     # hostile rule table
    assert shard_factor((64, 4096), ("batch", None),
                        {"data": 4, "pipe": 4}, rules) == 4


# ---------------------------------------------------------------------------
# pipeline memory semantics
# ---------------------------------------------------------------------------


def test_pp_reduces_per_stage_statics():
    """Splitting over stages shrinks per-device params/opt (that is the
    point of PP) while pp=1 keeps them whole."""
    shape = ShapeConfig("cell", 1024, 8, "train")
    whole = planner.check("llama3.2-3b", shape, {"data": 1, "model": 1})
    pp4 = planner.check("llama3.2-3b", shape,
                        {"data": 1, "model": 1, "pipe": 4})
    assert pp4.prediction.param_bytes < whole.prediction.param_bytes
    assert pp4.prediction.opt_bytes < whole.prediction.opt_bytes
    assert pp4.peak_bytes < whole.peak_bytes


def test_gpipe_stash_exceeds_1f1b():
    """GPipe holds all microbatches on every stage; 1F1B caps the stash
    at the remaining pipeline depth — so GPipe's peak is >=."""
    shape = ShapeConfig("cell", 1024, 16, "train")
    mesh = {"data": 1, "model": 1, "pipe": 4}
    f1b = planner.check("llama3.2-3b", shape, mesh, microbatches=8,
                        schedule="1f1b")
    gp = planner.check("llama3.2-3b", shape, mesh, microbatches=8,
                       schedule="gpipe")
    assert gp.peak_bytes >= f1b.peak_bytes
    assert gp.prediction.act_saved_bytes > f1b.prediction.act_saved_bytes


def test_boundary_buffers_on_middle_stages():
    """Middle stages carry 2 edges x (fwd + bwd) boundary buffers."""
    cfg = get_config("llama3.2-3b")
    model = build_model(cfg)
    ctx = planner.make_context(cfg, {"data": 1, "model": 1, "pipe": 4},
                               kind="train", global_batch=8, seq_len=1024)
    preds = PR.predict_stages(model, FULL_TRAIN, ctx)
    assert len(preds) == 4
    per_edge = ctx.pp_micro_batch * ctx.seq_len * cfg.d_model * 2
    raw = [PR._boundary_bytes(cfg, ctx, "train", s, 4) for s in range(4)]
    assert raw[0] == raw[3] == 2 * per_edge       # 1 edge x (fwd+bwd)
    assert raw[1] == raw[2] == 4 * per_edge       # 2 edges x (fwd+bwd)


# ---------------------------------------------------------------------------
# columnar == cell == un-memoized check on pp grids
# ---------------------------------------------------------------------------

PP_MESHES = [{"data": 2, "model": 2, "pipe": 1},
             {"data": 2, "model": 1, "pipe": 2},
             {"data": 1, "model": 2, "pipe": 4}]


@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_columnar_matches_cell_pp_grid(kind):
    np = pytest.importorskip("numpy")
    del np
    grid = SW.SweepGrid(
        arch="llava15-7b", mesh_shapes=PP_MESHES, kind=kind,
        schedules=("1f1b", "gpipe"), microbatches=(1, 4, 8),
        grad_accums=(1, 2) if kind == "train" else (1,),
        global_batches=(8, 16), seq_lens=(512,), backend="cpu")
    cell = SW.SweepEngine().sweep(grid, mode="cell")
    col = SW.SweepEngine().sweep(grid, mode="columnar")
    assert col.columns is not None
    assert len(cell) == len(col)
    for a, b in zip(cell.results, col.results):
        assert a == b, f"\ncell: {a!r}\ncol:  {b!r}"


def test_cell_path_matches_unmemoized_check_pp():
    grid = SW.SweepGrid(
        arch="smollm-360m", mesh_shapes=PP_MESHES,
        schedules=("1f1b", "gpipe"), microbatches=(1, 4),
        global_batches=(8,), seq_lens=(512,), backend="cpu")
    res = SW.SweepEngine().sweep(grid, mode="cell")
    for r in res.results:
        shape = ShapeConfig("cell", r.seq_len, r.global_batch, r.kind)
        ref = planner.check(r.arch, shape, r.mesh_shape,
                            backend=r.backend, grad_accum=r.grad_accum,
                            remat=r.remat, optimizer=r.optimizer,
                            chip=r.chip, microbatches=r.microbatches,
                            schedule=r.schedule)
        assert ref.peak_bytes == r.peak_bytes, r


def test_grid_size_counts_pp_knobs():
    grid = SW.SweepGrid(arch="smollm-360m", mesh_shapes=PP_MESHES,
                        schedules=("1f1b", "gpipe"),
                        microbatches=(1, 4, 8),
                        global_batches=(8, 16), seq_lens=(512,))
    assert grid.size() == 3 * 2 * 3 * 2
    assert grid.size() == sum(1 for _ in grid.cells())


def test_enumerate_meshes_pipe_axis():
    from repro.launch.mesh import enumerate_meshes, pp_degree
    meshes = enumerate_meshes(8, ("data", "model", "pipe"),
                              {"pipe": 2})
    assert all(m["data"] * m["model"] * m["pipe"] == 8 for m in meshes)
    assert {m["pipe"] for m in meshes} == {1, 2}
    assert pp_degree({"data": 2, "pipe": 4}) == 4
    assert pp_degree({"data": 2}) == 1


def test_plan_min_chips_pp_beats_no_pp():
    """PP unlocks configs dense 2-axis meshes cannot reach: the min-chip
    answer with the pipe axis allowed is never worse."""
    shape = ShapeConfig("cell", 2048, 8, "train")
    with_pp = planner.plan_min_chips(
        "llama3.2-3b", shape, chips=(2, 4, 8), max_pp=4,
        microbatches=(1, 4), schedules=("1f1b",))
    without = planner.plan_min_chips(
        "llama3.2-3b", shape, chips=(2, 4, 8), allow_pp=False)
    if without is None:
        assert with_pp is None or with_pp.fits
    else:
        assert with_pp is not None
        assert with_pp.n_chips <= without.n_chips


# ---------------------------------------------------------------------------
# CLI satellites
# ---------------------------------------------------------------------------


def test_breakdown_cli_smoke(capsys):
    from repro.configs.__main__ import main as cfg_main
    rc = cfg_main(["--breakdown", "--arch", "smollm_360m",
                   "--mesh", "data=2,model=1,pipe=2",
                   "--microbatches", "4"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "pipeline stages (pp=2" in out
    assert "per-module breakdown" in out
    assert "language_model/blocks" in out


def test_breakdown_cli_requires_arch():
    from repro.configs.__main__ import main as cfg_main
    with pytest.raises(SystemExit):
        cfg_main(["--breakdown"])


def test_breakdown_cli_liveness_slack(capsys):
    from repro.configs.__main__ import main as cfg_main
    argv = ["--breakdown", "--arch", "smollm_360m",
            "--mesh", "data=2,model=1,pipe=2", "--microbatches", "4"]
    rc = cfg_main(argv + ["--assembly", "liveness"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "liveness assembly" in out
    assert "overlap slack" in out and "ovl_slack" in out
    rc = cfg_main(argv)
    assert rc == 0
    legacy = capsys.readouterr().out
    assert "ovl_slack" not in legacy and "overlap slack" not in legacy


def test_breakdown_cli_assembly_needs_breakdown():
    from repro.configs.__main__ import main as cfg_main
    with pytest.raises(SystemExit):
        cfg_main(["--assembly", "liveness"])


def test_sweep_cli_pp_knobs(capsys):
    rc = SW.main(["--arch", "smollm_360m", "--chips", "8",
                  "--mesh-axes", "data,model,pipe", "--max-pipe", "2",
                  "--schedule", "1f1b,gpipe", "--microbatches", "1,4",
                  "--batch", "16", "--seq-len", "256", "--top", "5"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "gpipe" in out


def test_unknown_schedule_rejected_everywhere():
    grid = SW.SweepGrid(arch="smollm-360m", chips=4,
                        schedules=("interleaved",),
                        global_batches=(8,), seq_lens=(256,))
    for mode in ("columnar", "cell"):
        with pytest.raises(ValueError, match="unknown schedule"):
            SW.sweep(grid, mode=mode)
    with pytest.raises(SystemExit):       # clean argparse error, exit 2
        SW.main(["--arch", "smollm_360m", "--chips", "4", "--batch", "8",
                 "--schedule", "interleaved"])


def test_sweep_cli_dry_run_cardinality_table(capsys):
    rc = SW.main(["--arch", "smollm_360m", "--chips", "8",
                  "--mesh-axes", "data,model,pipe", "--max-pipe", "4",
                  "--schedule", "1f1b,gpipe", "--microbatches", "1,4,8",
                  "--batch", "16,32", "--seq-len", "512", "--dry-run"])
    assert rc == 0
    out = capsys.readouterr().out
    for knob in ("schedule", "microbatches", "accum x batch", "mesh",
                 "total"):
        assert knob in out
    assert "cells" in out and "estimated runtime" in out
