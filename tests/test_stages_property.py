"""Hypothesis property suite for the pipeline-stage partitioner.

Generates synthetic parse tables (random segment structures: pinned
front towers, splittable scan stacks, atomic oddballs, pinned tails) and
asserts the partition invariants — contiguity, exact cover, balance
bound, pinning — for arbitrary (rows, pp), including expert-stacked MoE
segments (the rows an expert-parallel mesh shards).  Runs whenever
``hypothesis`` is installed (skipped otherwise, like
tests/test_batch_property.py; CI installs it via requirements-dev.txt
and uses the shared "ci" profile from tests/conftest.py); the
deterministic twin over the real zoo lives in tests/test_stages.py.
"""

import pytest

hyp = pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis; `pip install hypothesis` "
           "to run them (CI does, via requirements-dev.txt)")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import stages as ST  # noqa: E402
from repro.core.parser import ParsedLayer  # noqa: E402
from repro.core.spec import LayerSpec, ParamSpec  # noqa: E402


def _mk_rows(segments):
    """segments: list of (module, modality, repeat, scanned, trainable,
    n_layers, width, kind) -> ParsedLayer rows.  kind "moe" builds an
    expert-stacked weight (leading `experts` axis, as models/moe.py
    does) so the partitioner property suite also covers MoE stacks —
    the rows an expert-parallel mesh axis shards."""
    rows = []
    for (module, modality, repeat, scanned, trainable, n_layers,
         width, kind) in segments:
        for li in range(n_layers):
            if kind == "moe":
                params = {"wg": ParamSpec(shape=(8, width, width),
                                          axes=("experts", None, None))}
            else:
                params = {"w": ParamSpec(shape=(width, width))}
            layer = LayerSpec(name=f"l{li}", kind=kind, params=params)
            rows.append(ParsedLayer(
                path=f"{module}/l{li}", module_path=module,
                modality=modality, layer=layer, repeat=repeat,
                scanned=scanned, trainable=trainable))
    return rows


@st.composite
def model_shapes(draw):
    segs = []
    n_front = draw(st.integers(0, 2))
    for i in range(n_front):
        segs.append((f"front{i}", draw(st.sampled_from(
            ["vision", "audio", "text"])), 1, False,
            draw(st.booleans()), draw(st.integers(1, 3)),
            draw(st.sampled_from([8, 16])), "linear"))
    n_mid = draw(st.integers(1, 3))
    for i in range(n_mid):
        segs.append((f"mid{i}", "text", draw(st.integers(2, 24)), True,
                     draw(st.booleans()), draw(st.integers(1, 4)),
                     draw(st.sampled_from([8, 16, 32])),
                     draw(st.sampled_from(["linear", "moe"]))))
    n_tail = draw(st.integers(0, 2))
    for i in range(n_tail):
        segs.append((f"tail{i}", "text", 1, False, draw(st.booleans()),
                     draw(st.integers(1, 2)),
                     draw(st.sampled_from([8, 16])), "linear"))
    return _mk_rows(segs)


@settings(max_examples=200, deadline=None)
@given(rows=model_shapes(), pp=st.integers(1, 8))
def test_partition_invariants(rows, pp):
    plan = ST.partition(rows, pp)
    assert len(plan.stages) == pp

    flat = [r for s in plan.stages for r in s]
    # exact cover: per-path repeats conserved
    by_path: dict = {}
    for r in flat:
        by_path[r.path] = by_path.get(r.path, 0) + r.repeat
    assert by_path == {r.path: r.repeat for r in rows}

    # contiguity: flattened stage order walks the original segment
    # order, and a split segment spans a contiguous run of stages
    seg_order: dict = {}
    for r in rows:
        seg_order.setdefault(r.module_path, len(seg_order))
    idx = [seg_order[r.module_path] for r in flat]
    assert idx == sorted(idx)
    holders: dict = {}
    for si, s in enumerate(plan.stages):
        for r in s:
            holders.setdefault(r.module_path, []).append(si)
    for sis in holders.values():
        uniq = sorted(set(sis))
        assert uniq == list(range(uniq[0], uniq[-1] + 1))

    # weights bookkeeping matches the rows actually assigned
    for s_rows, w in zip(plan.stages, plan.weights):
        got = sum(sum(p.nbytes for p in r.layer.params.values())
                  * r.repeat * (ST.TRAINABLE_WEIGHT if r.trainable else 1)
                  for r in s_rows)
        assert got == w


@settings(max_examples=100, deadline=None)
@given(rows=model_shapes(), pp=st.integers(2, 8))
def test_partition_balance_bound(rows, pp):
    segs = ST._segments(rows)
    split_ids = [i for i, s in enumerate(segs) if s.splittable]
    plan = ST.partition(rows, pp)
    if not split_ids:
        assert plan.weights[1:] == (0,) * (pp - 1)
        return
    first, last = split_ids[0], split_ids[-1]
    front = sum(s.total_weight() for s in segs[:first])
    tail = sum(s.total_weight() for s in segs[last + 1:])
    units = []
    for seg in segs[first:last + 1]:
        units.extend([seg.unit_weight()] * seg.repeat if seg.splittable
                     else [seg.total_weight()])
    bound = max(front, tail) + -(-sum(units) // pp) + max(units)
    assert max(plan.weights) <= bound


@settings(max_examples=100, deadline=None)
@given(rows=model_shapes(), pp=st.integers(2, 6))
def test_partition_pins_non_text_towers(rows, pp):
    plan = ST.partition(rows, pp)
    for si, stage in enumerate(plan.stages):
        for r in stage:
            if r.modality in ("vision", "audio"):
                assert si == 0


@settings(max_examples=100, deadline=None)
@given(pp=st.integers(1, 8), m=st.integers(1, 16),
       sched=st.sampled_from(ST.SCHEDULES))
def test_stash_count_bounds(pp, m, sched):
    counts = [ST.stash_count(s, pp, m, sched) for s in range(pp)]
    assert all(1 <= c <= max(m, 1) for c in counts)
    if pp == 1:
        assert counts == [1]
    elif sched == "gpipe":
        assert counts == [m] * pp
    else:
        assert counts == sorted(counts, reverse=True)   # drains down
        assert counts[-1] == 1 if m >= 1 else True
