"""Capacity-planning sweep engine (core/sweep.py + launch/mesh.py).

Covers the ISSUE-1 test checklist: exhaustive + deduplicated mesh
enumeration, byte-identical memoized vs cell-by-cell evaluation, monotone
Pareto queries, and a CLI smoke run.
"""

import math

import pytest

from repro.configs import ShapeConfig
from repro.core import planner, sweep as SW
from repro.launch.mesh import (divisors, enumerate_meshes, factorizations,
                               mesh_chips)

# ---------------------------------------------------------------------------
# mesh factorization enumeration
# ---------------------------------------------------------------------------


def test_divisors():
    assert divisors(1) == [1]
    assert divisors(16) == [1, 2, 4, 8, 16]
    assert divisors(12) == [1, 2, 3, 4, 6, 12]


@pytest.mark.parametrize("n,k", [(16, 2), (256, 2), (8, 3), (12, 3)])
def test_factorizations_exhaustive_and_deduplicated(n, k):
    facts = factorizations(n, k)
    # every tuple multiplies back to n
    assert all(math.prod(f) == n for f in facts)
    # deduplicated
    assert len(facts) == len(set(facts))
    # exhaustive: brute-force count over all k-tuples of divisors
    divs = divisors(n)
    brute = {t for t in _tuples(divs, k) if math.prod(t) == n}
    assert set(facts) == brute


def _tuples(vals, k):
    if k == 0:
        yield ()
        return
    for v in vals:
        for rest in _tuples(vals, k - 1):
            yield (v,) + rest


def test_enumerate_meshes_named_axes():
    meshes = enumerate_meshes(16, ("data", "model"))
    assert len(meshes) == 5          # 1x16, 2x8, 4x4, 8x2, 16x1
    assert all(mesh_chips(m) == 16 for m in meshes)
    # named axes: data=8/model=2 and data=2/model=8 are distinct plans
    assert {"data": 8, "model": 2} in meshes
    assert {"data": 2, "model": 8} in meshes
    # deduplicated
    keyed = [tuple(sorted(m.items())) for m in meshes]
    assert len(keyed) == len(set(keyed))


def test_enumerate_meshes_max_axis_cap():
    meshes = enumerate_meshes(256, ("data", "model"),
                              max_axis={"model": 16})
    assert all(m["model"] <= 16 for m in meshes)
    assert {"data": 16, "model": 16} in meshes


def test_enumerate_meshes_three_axes():
    meshes = enumerate_meshes(8, ("pod", "data", "model"))
    assert len(meshes) == 10         # ordered exponent splits of 2^3
    assert all(mesh_chips(m) == 8 for m in meshes)


# ---------------------------------------------------------------------------
# memoized sweep == cell-by-cell check, byte for byte
# ---------------------------------------------------------------------------


def test_sweep_matches_cell_by_cell_check():
    grid = SW.SweepGrid(
        arch="smollm-360m", chips=8,
        optimizers=(None, "adafactor"),
        remats=(None, "none"),
        grad_accums=(1, 2),
        global_batches=(16, 32),
        seq_lens=(512,),
        backend="tpu", keep_predictions=True)
    res = SW.sweep(grid)
    assert len(res) > 50
    for r in res:
        shape = ShapeConfig("cell", r.seq_len, r.global_batch, r.kind)
        ref = planner.check(r.arch, shape, r.mesh_shape, backend=r.backend,
                            grad_accum=r.grad_accum, remat=r.remat,
                            optimizer=r.optimizer, chip=r.chip)
        assert ref.peak_bytes == r.peak_bytes
        assert ref.fits == r.fits
        # the full prediction (all Eq.1 terms + per-module breakdown)
        # must be identical, not just the total
        assert ref.prediction == r.prediction


def test_sweep_cache_hits_are_identical_to_cold():
    cell = next(SW.SweepGrid(arch="smollm-360m", chips=4,
                             global_batches=(16,), seq_lens=(256,)).cells())
    engine = SW.SweepEngine()
    cold = engine.evaluate(cell, keep_prediction=True)
    warm = engine.evaluate(cell, keep_prediction=True)
    assert cold == warm


def test_report_matches_check():
    mesh = {"data": 4, "model": 2}
    budget = int(planner.chip_hbm("v5e") * planner.HEADROOM)
    eng = SW.SweepEngine()
    a = eng.report("smollm-360m", "train_4k", mesh, backend="tpu",
                   budget_bytes=budget, grad_accum=2)
    b = planner.check("smollm-360m", "train_4k", mesh, backend="tpu",
                      grad_accum=2)
    assert (a.peak_bytes, a.fits, a.budget_bytes) == \
        (b.peak_bytes, b.fits, b.budget_bytes)
    assert a.prediction == b.prediction


# ---------------------------------------------------------------------------
# Pareto queries
# ---------------------------------------------------------------------------


def _grid_for(chips):
    # batches are multiples of every chip count so DP divisibility never
    # degrades to replication at higher chip counts
    return SW.SweepGrid(arch="smollm-360m", chips=chips,
                        grad_accums=(1, 2, 4),
                        global_batches=(32, 64, 128, 256, 512),
                        seq_lens=(1024,), backend="tpu")


def test_pareto_max_batch_monotone_in_chips():
    engine = SW.SweepEngine()
    prev = 0
    for chips in (4, 8, 16, 32):
        res = engine.sweep(_grid_for(chips))
        best = res.max_global_batch()
        batch = best.global_batch if best else 0
        assert batch >= prev, \
            f"{chips} chips fits batch {batch} < {prev} on fewer chips"
        prev = batch


def test_pareto_queries_consistent():
    res = SW.sweep(_grid_for((8, 16)))
    fit = res.fitting()
    if not fit:
        pytest.skip("nothing fits this grid")
    best = res.max_global_batch()
    assert best.fits
    assert best.global_batch == max(r.global_batch for r in fit)
    nb = res.max_global_batch(n_chips=8)
    if nb is not None:
        assert nb.n_chips == 8
    least = res.min_chips()
    assert least.n_chips == min(r.n_chips for r in fit)
    frontier = res.frontier()
    assert frontier == sorted(frontier)
    for chips, batch in frontier:
        assert res.max_global_batch(n_chips=chips).global_batch == batch


def test_min_chips_at_fixed_batch():
    res = SW.sweep(_grid_for((8, 16)))
    r = res.min_chips(global_batch=64)
    if r is not None:
        assert r.global_batch == 64
        assert r.fits


# ---------------------------------------------------------------------------
# chip table + report writers + CLI
# ---------------------------------------------------------------------------


def test_chip_table():
    assert planner.chip_hbm("v5e") == 16 * 1024 ** 3
    assert planner.V5E_HBM == planner.chip_hbm("v5e")
    assert planner.chip_hbm("h100") == 80 * 1024 ** 3
    with pytest.raises(KeyError):
        planner.chip_hbm("abacus")


def test_bigger_chip_fits_more():
    mesh = {"data": 2, "model": 2}
    shape = ShapeConfig("cell", 1024, 16, "train")
    v5e = planner.check("llama3.2-3b", shape, mesh, chip="v5e")
    h200 = planner.check("llama3.2-3b", shape, mesh, chip="h200")
    assert h200.budget_bytes > v5e.budget_bytes
    assert h200.peak_bytes == v5e.peak_bytes      # prediction is chip-free


def test_report_writers():
    res = SW.sweep(SW.SweepGrid(arch="smollm-360m", chips=4,
                                global_batches=(16,), seq_lens=(256,)))
    md = res.to_markdown(limit=3)
    assert "| arch" in md and "smollm-360m" in md
    csv = res.to_csv()
    assert csv.splitlines()[0].startswith("arch,chip,mesh")
    assert len(csv.splitlines()) == len(res) + 1


def test_normalize_arch():
    assert SW.normalize_arch("llava15_7b") == "llava15-7b"
    assert SW.normalize_arch("llama3_2_3b") == "llama3.2-3b"
    assert SW.normalize_arch("smollm-360m") == "smollm-360m"
    with pytest.raises(KeyError):
        SW.normalize_arch("gpt17")


def test_cli_smoke(capsys):
    rc = SW.main(["--arch", "smollm_360m", "--chips", "4",
                  "--batch", "16,32", "--accum", "1,2",
                  "--seq-len", "512", "--top", "5"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "cells in" in out
    assert "smollm-360m" in out


def test_cli_requires_mesh_or_chips():
    with pytest.raises(SystemExit):
        SW.main(["--arch", "smollm-360m"])


def test_cli_empty_grid_exits_2_with_message(capsys):
    # no --batch value divisible by the only --accum value -> 0 cells
    rc = SW.main(["--arch", "smollm_360m", "--chips", "4",
                  "--batch", "3,9", "--accum", "2", "--seq-len", "512"])
    assert rc == 2
    out = capsys.readouterr().out
    assert "0 cells matched" in out
    assert "|" not in out            # no empty table


def test_cli_cell_mode(capsys):
    rc = SW.main(["--arch", "smollm_360m", "--chips", "4",
                  "--batch", "16", "--seq-len", "256", "--mode", "cell",
                  "--top", "3"])
    assert rc == 0
    assert "mode=cell" in capsys.readouterr().out


def test_cli_dry_run_counts_without_evaluating(capsys):
    rc = SW.main(["--arch", "smollm_360m", "--chips", "256",
                  "--batch", "64,128", "--accum", "1,2",
                  "--seq-len", "1024,2048", "--remat", "none,block",
                  "--dry-run"])
    assert rc == 0
    out = capsys.readouterr().out
    # 9 meshes x 2 remats x 4 (accum, batch) pairs x 2 seqs = 144
    assert "144 cells" in out
    assert "estimated runtime" in out
    assert "cells in" not in out     # nothing was evaluated


def test_cli_dry_run_empty_grid_exits_2(capsys):
    rc = SW.main(["--arch", "smollm_360m", "--chips", "4",
                  "--batch", "3", "--accum", "2", "--seq-len", "512",
                  "--dry-run"])
    assert rc == 2
    assert "0 cells matched" in capsys.readouterr().out


def test_grid_size_counts_divisibility_filter():
    grid = SW.SweepGrid(arch="smollm-360m", chips=4, grad_accums=(1, 2, 3),
                        global_batches=(6, 8, 9), seq_lens=(256,))
    # pairs: accum 1 x {6,8,9}, accum 2 x {6,8}, accum 3 x {6,9} = 7
    assert grid.size() == len(SW.SweepGrid(
        arch="smollm-360m", chips=4).meshes()) * 7
    assert grid.size() == sum(1 for _ in grid.cells())


# ---------------------------------------------------------------------------
# expert-parallel / context-parallel negative paths (ISSUE-5): invalid
# combos must die with ONE clean ValueError from planner.check_parallel —
# identical across planner.check, both sweep modes, and the CLI.
# ---------------------------------------------------------------------------


def test_check_rejects_ep_on_dense_arch():
    shape = ShapeConfig("cell", 512, 8, "train")
    with pytest.raises(ValueError, match="dense arch"):
        planner.check("smollm-360m", shape, {"data": 2, "expert": 2})


def test_check_rejects_ep_beyond_expert_count():
    shape = ShapeConfig("cell", 512, 8, "train")
    with pytest.raises(ValueError, match="routed experts"):
        planner.check("deepseek-v2-lite-16b", shape,
                      {"data": 1, "expert": 128})


def test_check_rejects_non_divisible_ep():
    """ep <= n_experts but non-divisible would be silently inert in the
    model (rule never applies) and unrunnable by the EP all_to_all."""
    shape = ShapeConfig("cell", 512, 8, "train")
    with pytest.raises(ValueError, match="does not divide"):
        planner.check("deepseek-v2-lite-16b", shape,   # 64 % 3 != 0
                      {"data": 1, "expert": 3})


def test_check_rejects_cp_on_decode():
    shape = ShapeConfig("cell", 512, 8, "decode")
    with pytest.raises(ValueError, match="invalid for decode"):
        planner.check("llama3.2-3b", shape, {"data": 2, "context": 2})


def test_check_rejects_non_divisible_cp():
    shape = ShapeConfig("cell", 1000, 8, "train")
    with pytest.raises(ValueError, match="does not divide seq_len"):
        planner.check("llama3.2-3b", shape, {"data": 2, "context": 3})


def test_check_accepts_trivial_ep_cp_axes():
    """Size-1 expert/context axes are inert, whatever the arch/kind."""
    for kind in ("train", "prefill", "decode"):
        shape = ShapeConfig("cell", 512, 8, kind)
        r = planner.check("smollm-360m", shape,
                          {"data": 2, "expert": 1, "context": 1})
        base = planner.check("smollm-360m", shape, {"data": 2})
        assert r.peak_bytes == base.peak_bytes, kind


@pytest.mark.parametrize("mode", ["columnar", "cell"])
def test_sweep_rejects_invalid_ep_cp_grids(mode):
    bad_grids = [
        SW.SweepGrid(arch="smollm-360m",                  # dense + ep
                     mesh_shapes=[{"data": 2, "expert": 2}],
                     global_batches=(8,), seq_lens=(512,)),
        SW.SweepGrid(arch="deepseek-v2-lite-16b",         # decode + cp
                     mesh_shapes=[{"data": 2, "context": 2}],
                     kind="decode",
                     global_batches=(8,), seq_lens=(512,)),
        SW.SweepGrid(arch="deepseek-v2-lite-16b",         # cp % seq != 0
                     mesh_shapes=[{"data": 2, "context": 4}],
                     global_batches=(8,), seq_lens=(1022,)),
        SW.SweepGrid(arch="deepseek-v2-lite-16b",         # ep > n_experts
                     mesh_shapes=[{"expert": 128}],
                     global_batches=(8,), seq_lens=(512,)),
    ]
    for grid in bad_grids:
        with pytest.raises(ValueError):
            SW.sweep(grid, mode=mode)


def test_sweep_cli_rejects_invalid_ep_cp(capsys):
    cases = [
        (["--arch", "smollm_360m", "--chips", "8", "--mesh-axes",
          "data,expert", "--batch", "8", "--seq-len", "512"],
         "dense arch"),
        (["--arch", "deepseek_v2_lite_16b", "--chips", "8", "--mesh-axes",
          "data,context", "--kind", "decode", "--batch", "8",
          "--seq-len", "512"],
         "invalid for decode"),
        (["--arch", "deepseek_v2_lite_16b", "--chips", "8", "--mesh-axes",
          "data,context", "--batch", "8", "--seq-len", "1023"],
         "does not divide seq_len"),
    ]
    for argv, needle in cases:
        with pytest.raises(SystemExit) as exc:    # clean argparse error
            SW.main(argv)
        assert exc.value.code == 2
        assert needle in capsys.readouterr().err


def test_sweep_cli_ep_cp_knobs(capsys):
    rc = SW.main(["--arch", "deepseek_v2_lite_16b", "--chips", "16",
                  "--mesh-axes", "data,model,expert,context",
                  "--max-expert", "4", "--max-context", "2",
                  "--batch", "16", "--seq-len", "512", "--top", "5"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "expert=" in out and "context=" in out


def test_plan_min_chips_ep_cp_never_worse():
    """Allowing the expert/context axes can only unlock configs, never
    lose them (the 2-axis plans stay in the enumerated set)."""
    shape = ShapeConfig("cell", 1024, 8, "train")
    base = planner.plan_min_chips(
        "deepseek-v2-lite-16b", shape, chips=(8, 16), allow_pp=False)
    epcp = planner.plan_min_chips(
        "deepseek-v2-lite-16b", shape, chips=(8, 16), allow_pp=False,
        allow_ep=True, max_ep=4, allow_cp=True, max_cp=4)
    if base is not None:
        assert epcp is not None
        assert epcp.n_chips <= base.n_chips


def test_report_writers_render_serve_columns():
    """Regression (ISSUE-6): grids with active serving-fleet knobs must
    carry the serve columns in BOTH writers instead of silently dropping
    the pool/draft/hit-savings fields; neutral grids keep the old
    column set exactly."""
    grid = SW.SweepGrid(arch="smollm-360m", kind="decode",
                        mesh_shapes=({"data": 2},),
                        global_batches=(8,), seq_lens=(512,),
                        block_sizes=(16,), utilizations=(0.9,),
                        prefix_hit_rates=(0.5,), prefix_len=128)
    res = SW.sweep(grid)
    md, csv = res.to_markdown(limit=2), res.to_csv()
    for col in ("block", "blocks_per_seq", "hit", "pool_gib",
                "hit_saved_gib", "draft_gib"):
        assert col in md and col in csv.splitlines()[0], col
    assert len(csv.splitlines()) == len(res) + 1
    neutral = SW.sweep(SW.SweepGrid(arch="smollm-360m", kind="decode",
                                    mesh_shapes=({"data": 2},),
                                    global_batches=(8,),
                                    seq_lens=(512,)))
    assert "pool_gib" not in neutral.to_markdown(limit=2)
    assert "pool_gib" not in neutral.to_csv().splitlines()[0]


def test_report_writers_render_liveness_slack_column():
    """Regression (ISSUE-9): liveness-assembly sweeps carry the
    reporting-only overlap-slack column in BOTH writers; legacy sweeps
    keep the old column set exactly."""
    mk = lambda asm: SW.SweepGrid(arch="smollm-360m",
                                  mesh_shapes=({"data": 2, "model": 2},),
                                  global_batches=(8,), seq_lens=(512,),
                                  assembly=asm)
    live = SW.sweep(mk("liveness"))
    md, csv = live.to_markdown(limit=2), live.to_csv()
    assert "ovl_slack_gib" in md
    assert "ovl_slack_gib" in csv.splitlines()[0]
    assert len(csv.splitlines()) == len(live) + 1
    slack = [r.overlap_slack_bytes for r in live]
    assert all(s >= 0 for s in slack) and any(s > 0 for s in slack)
    legacy = SW.sweep(mk("legacy"))
    assert "ovl_slack_gib" not in legacy.to_markdown(limit=2)
    assert "ovl_slack_gib" not in legacy.to_csv().splitlines()[0]
    assert all(r.overlap_slack_bytes == 0 for r in legacy)
