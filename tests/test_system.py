"""End-to-end system tests: serving consistency, sharded-vs-unsharded
training equivalence (4 fake devices, subprocess), MoE expert parallelism,
and a miniature dry-run (the deliverable-(e) machinery on a tiny mesh)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.conftest import run_with_devices, tiny_batch
from repro.configs import ShapeConfig, get_config
from repro.models import build_model
from repro.serve import generate


def test_generate_greedy_consistency():
    """generate() equals argmax teacher-forcing over the model's own
    choices (prefill + incremental decode correctness end-to-end)."""
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab)
    out = generate(model, params, {"tokens": tokens}, max_new_tokens=6)
    assert out.shape == (2, 6)

    # oracle: re-run full forward over (prompt + generated prefix)
    seq = tokens
    for t in range(6):
        logits, _ = jax.jit(model.prefill)(params, {"tokens": seq})
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(out[:, t:t + 1]),
                                      np.asarray(nxt))
        seq = jnp.concatenate([seq, nxt], axis=1)


def test_sharded_training_matches_single_device():
    """The production sharding path (mesh + ZeRO + TP + SP constraints)
    computes the SAME numbers as the unsharded program."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ShapeConfig, get_config
from repro.core.spec import FULL_TRAIN
from repro.launch import mesh as M
from repro.mesh_ctx import mesh_context
from repro.models import build_model, param as PM
from repro.train import OptimizerConfig, TrainState, make_train_step
from repro.train.optimizer import init_opt_state

cfg = get_config('smollm-360m').reduced()
model = build_model(cfg)
shape = ShapeConfig('t', 32, 4, 'train')
key = jax.random.PRNGKey(0)
params = model.init(key)
mask = PM.trainable_mask(model.spec, FULL_TRAIN)
tr, _ = PM.partition_params(params, mask)
opt = init_opt_state(tr, OptimizerConfig())
state = TrainState(params=params, opt=opt, step=jnp.int32(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
batch = {'tokens': tokens, 'labels': tokens}

# unsharded
step = jax.jit(make_train_step(model, FULL_TRAIN, OptimizerConfig()))
s1, m1 = step(state, batch)

# sharded on a (2, 2) mesh with the full production rules
mesh = M.make_smoke_mesh(2, 2)
with mesh_context(mesh, M.arch_rules(cfg)):
    step2 = jax.jit(make_train_step(model, FULL_TRAIN, OptimizerConfig()))
    s2, m2 = step2(state, batch)

assert abs(float(m1['loss']) - float(m2['loss'])) < 1e-3, (m1, m2)
d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
    a.astype(jnp.float32) - b.astype(jnp.float32)))), s1.params, s2.params)
worst = max(jax.tree.leaves(d))
assert worst < 5e-2, worst
print('SHARDED_OK', float(m1['loss']), float(m2['loss']), worst)
"""
    out = run_with_devices(code, n_devices=4)
    assert "SHARDED_OK" in out


def test_moe_ep_matches_dense_fallback():
    """Expert-parallel all_to_all dispatch == dense fallback when no
    tokens are dropped (high capacity factor)."""
    code = """
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get_config
from repro.launch import mesh as M
from repro.mesh_ctx import mesh_context
from repro.models.moe import moe_forward, moe_spec

cfg = get_config('deepseek-v2-lite-16b').reduced()
moe = dataclasses.replace(cfg.moe, capacity_factor=8.0)  # no drops
spec = moe_spec('ffn', cfg.d_model, moe, cfg.dtype)
key = jax.random.PRNGKey(0)
p = {
  'router': jax.random.normal(key, (cfg.d_model, moe.n_experts), jnp.float32) * 0.1,
  'wg': jax.random.normal(jax.random.PRNGKey(1), (moe.n_experts, cfg.d_model, moe.d_expert), jnp.float32) * 0.05,
  'wu': jax.random.normal(jax.random.PRNGKey(2), (moe.n_experts, cfg.d_model, moe.d_expert), jnp.float32) * 0.05,
  'wd': jax.random.normal(jax.random.PRNGKey(3), (moe.n_experts, moe.d_expert, cfg.d_model), jnp.float32) * 0.05,
}
if moe.n_shared_experts:
    Fs = moe.d_expert * moe.n_shared_experts
    p.update({'shared_wg': jnp.zeros((cfg.d_model, Fs)),
              'shared_wu': jnp.zeros((cfg.d_model, Fs)),
              'shared_wd': jnp.zeros((Fs, cfg.d_model))})
x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, cfg.d_model), jnp.float32) * 0.5
meta = dict(spec.meta, capacity_factor=8.0)

y_dense, aux_dense = moe_forward(p, x, meta)            # no mesh -> dense
mesh = M.make_smoke_mesh(2, 2)
with mesh_context(mesh):
    y_ep, aux_ep = jax.jit(lambda p, x: moe_forward(p, x, meta))(p, x)
err = float(jnp.max(jnp.abs(y_dense - y_ep)))
assert err < 2e-3, err
assert abs(float(aux_dense) - float(aux_ep)) < 1e-3
print('MOE_EP_OK', err)
"""
    out = run_with_devices(code, n_devices=4)
    assert "MOE_EP_OK" in out


def test_mini_dryrun_machinery():
    """lower+compile+memory/cost/collective extraction on a 2x2 mesh —
    the exact deliverable-(e) code path, reduced."""
    code = """
import jax, jax.numpy as jnp
from repro.configs import ShapeConfig, get_config
from repro.core import xla_metrics as XM
from repro.core.spec import FULL_TRAIN
from repro.launch import mesh as M
from repro.mesh_ctx import mesh_context
from repro.models import build_model, param as PM
from repro.train import OptimizerConfig, TrainState, make_train_step
from repro.train.optimizer import opt_state_specs

cfg = get_config('llama3.2-3b').reduced()
model = build_model(cfg)
mesh = M.make_smoke_mesh(2, 2)
shape = ShapeConfig('t', 64, 4, 'train')
with mesh_context(mesh, M.arch_rules(cfg)):
    batch = model.batch_spec(shape)
    bsh = M.batch_shardings(mesh, batch)
    params = model.param_specs()
    mask = PM.trainable_mask(model.spec, FULL_TRAIN)
    tr, _ = PM.partition_params(params, mask)
    opt = opt_state_specs(tr, OptimizerConfig())
    state = TrainState(params=params, opt=opt,
                       step=jax.ShapeDtypeStruct((), jnp.int32))
    step = make_train_step(model, FULL_TRAIN, OptimizerConfig())
    lowered = jax.jit(step, in_shardings=(None, bsh)).lower(state, batch)
    compiled = lowered.compile()
mem = XM.memory_stats(compiled)
cost = XM.cost_stats(compiled)
coll = XM.collective_stats(compiled.as_text(), 4)
assert mem.total_bytes > 0 and cost.flops > 0
assert sum(coll.counts.values()) > 0, coll.counts
print('DRYRUN_OK', mem.total_bytes, cost.flops, coll.counts)
"""
    out = run_with_devices(code, n_devices=4)
    assert "DRYRUN_OK" in out


def test_int8_grad_compression_trains():
    from repro.core.spec import FULL_TRAIN
    from repro.models import param as PM
    from repro.train import OptimizerConfig, TrainState, make_train_step
    from repro.train.optimizer import init_opt_state
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mask = PM.trainable_mask(model.spec, FULL_TRAIN)
    tr, _ = PM.partition_params(params, mask)
    state = TrainState(params=params,
                       opt=init_opt_state(tr, OptimizerConfig()),
                       step=jnp.int32(0))
    batch = tiny_batch(model, ShapeConfig("t", 32, 2, "train"))
    step = jax.jit(make_train_step(model, FULL_TRAIN, OptimizerConfig(),
                                   compress_grads=True))
    state2, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
