"""Loop-aware HLO accounting: exactness on known programs.

XLA's cost_analysis counts while-loop bodies once; the roofline relies on
our trip-count-aware walker, so its numbers must be provably right."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.xla_metrics import (collective_stats, loop_aware_stats,
                                    shape_bytes)


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_flat_scan_flops_exact():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jnp.zeros((128, 256), jnp.float32)
    w = jnp.zeros((256, 256), jnp.float32)
    s = loop_aware_stats(_compile(f, x, w).as_text(), 1)
    assert s.flops == 10 * 2 * 128 * 256 * 256


def test_nested_scan_flops_exact():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    x = jnp.zeros((64, 128), jnp.float32)
    w = jnp.zeros((128, 128), jnp.float32)
    s = loop_aware_stats(_compile(g, x, w).as_text(), 1)
    assert s.flops == 4 * 5 * 2 * 64 * 128 * 128


def test_unlooped_dot_counted_once():
    def f(x, w):
        return x @ w

    x = jnp.zeros((32, 64), jnp.float32)
    w = jnp.zeros((64, 16), jnp.float32)
    s = loop_aware_stats(_compile(f, x, w).as_text(), 1)
    assert s.flops == 2 * 32 * 64 * 16


def test_bytes_nonzero_and_scale_with_trip_count():
    def make(n):
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=n)
            return y
        return f

    x = jnp.zeros((64, 64), jnp.float32)
    w = jnp.zeros((64, 64), jnp.float32)
    s2 = loop_aware_stats(_compile(make(2), x, w).as_text(), 1)
    s8 = loop_aware_stats(_compile(make(8), x, w).as_text(), 1)
    assert s8.flops == 4 * s2.flops
    assert s8.bytes_accessed > 2 * s2.bytes_accessed


def test_shape_bytes():
    assert shape_bytes("f32[2,3]") == 24
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(f32[2], s32[4])") == 24
    assert shape_bytes("pred[]") == 1
