"""Loop-aware HLO accounting: exactness on known programs.

XLA's cost_analysis counts while-loop bodies once; the roofline relies on
our trip-count-aware walker, so its numbers must be provably right."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.xla_metrics import (collective_stats, loop_aware_stats,
                                    shape_bytes)


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_flat_scan_flops_exact():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jnp.zeros((128, 256), jnp.float32)
    w = jnp.zeros((256, 256), jnp.float32)
    s = loop_aware_stats(_compile(f, x, w).as_text(), 1)
    assert s.flops == 10 * 2 * 128 * 256 * 256


def test_nested_scan_flops_exact():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    x = jnp.zeros((64, 128), jnp.float32)
    w = jnp.zeros((128, 128), jnp.float32)
    s = loop_aware_stats(_compile(g, x, w).as_text(), 1)
    assert s.flops == 4 * 5 * 2 * 64 * 128 * 128


def test_unlooped_dot_counted_once():
    def f(x, w):
        return x @ w

    x = jnp.zeros((32, 64), jnp.float32)
    w = jnp.zeros((64, 16), jnp.float32)
    s = loop_aware_stats(_compile(f, x, w).as_text(), 1)
    assert s.flops == 2 * 32 * 64 * 16


def test_bytes_nonzero_and_scale_with_trip_count():
    def make(n):
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=n)
            return y
        return f

    x = jnp.zeros((64, 64), jnp.float32)
    w = jnp.zeros((64, 64), jnp.float32)
    s2 = loop_aware_stats(_compile(make(2), x, w).as_text(), 1)
    s8 = loop_aware_stats(_compile(make(8), x, w).as_text(), 1)
    assert s8.flops == 4 * s2.flops
    assert s8.bytes_accessed > 2 * s2.bytes_accessed


def test_shape_bytes():
    assert shape_bytes("f32[2,3]") == 24
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(f32[2], s32[4])") == 24
    assert shape_bytes("pred[]") == 1


# ---------------------------------------------------------------------------
# shape_bytes edge cases + collective_stats text parsing (pure-text ground
# truth the calibration MeasurementStore ingest now depends on)
# ---------------------------------------------------------------------------


def test_shape_bytes_scalar_empty_dims():
    # empty dims = rank-0 scalar: one element of the dtype
    assert shape_bytes("f32[]") == 4
    assert shape_bytes("bf16[]") == 2
    assert shape_bytes("s64[]") == 8


def test_shape_bytes_zero_dim():
    assert shape_bytes("f32[0]") == 0
    assert shape_bytes("f32[4,0,8]") == 0


def test_shape_bytes_f8_dtypes():
    assert shape_bytes("f8e4m3fn[16]") == 16
    assert shape_bytes("f8e5m2[4,4]") == 16
    # f8 inside a tuple alongside wider dtypes
    assert shape_bytes("(f8e4m3fn[8], f32[8])") == 8 + 32


def test_shape_bytes_nested_tuples_and_noise():
    # every typed shape in the string counts, once each
    assert shape_bytes("(f32[2,2], (bf16[4], s32[1]))") == 16 + 8 + 4
    # surrounding HLO noise does not confuse the scan
    line = "%x = f32[128,64] dot(%a, %b), lhs_contracting_dims={1}"
    assert shape_bytes("f32[128,64]") == 128 * 64 * 4
    assert shape_bytes(line.split("=")[1].split("dot")[0].strip()) \
        == 128 * 64 * 4


def test_shape_bytes_no_match():
    assert shape_bytes("") == 0
    assert shape_bytes("tuple()") == 0
    assert shape_bytes("token[]") == 0          # untyped token: no bytes


ASYNC_HLO = """\
ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024] parameter(0)
  %ar-start = f32[1024] all-reduce-start(%p0), replica_groups={{0,1,2,3}}
  %ar-done = f32[1024] all-reduce-done(%ar-start)
  %ag = f32[4096] all-gather(%ar-done), replica_groups={{0,1,2,3}}
  ROOT %cp = f32[4096] collective-permute(%ag), source_target_pairs={{0,1}}
}
"""


def test_collective_stats_start_done_counted_once():
    s = collective_stats(ASYNC_HLO, n_devices=4)
    # the -start/-done pair is ONE all-reduce, counted at -start
    assert s.counts == {"all-reduce": 1, "all-gather": 1,
                        "collective-permute": 1}
    assert s.operand_bytes["all-reduce"] == 1024 * 4
    # ring wire estimates: AR 2x(g-1)/g, AG (g-1)/g, permute 1x
    assert s.wire_bytes["all-reduce"] == int(2 * 4096 * 3 / 4)
    assert s.wire_bytes["all-gather"] == int(4096 * 4 * 3 / 4)
    assert s.wire_bytes["collective-permute"] == 4096 * 4


def test_collective_stats_group_size_from_replica_groups():
    hlo = ("%ar = f32[256] all-reduce(%x), replica_groups={{0,1}}\n"
           "%ar2 = f32[256] all-reduce(%y), replica_groups={{0,1,2,3,4,5,6,7}}\n")
    s = collective_stats(hlo, n_devices=64)
    assert s.counts["all-reduce"] == 2
    # first group has 2 members, second 8 — wire bytes reflect each
    expected = int(2 * 1024 * 1 / 2) + int(2 * 1024 * 7 / 8)
    assert s.wire_bytes["all-reduce"] == expected


def test_collective_stats_default_group_size():
    # no replica_groups annotation -> all n_devices participate
    hlo = "%ar = f32[100] all-reduce(%x)\n"
    s = collective_stats(hlo, n_devices=8)
    assert s.wire_bytes["all-reduce"] == int(2 * 400 * 7 / 8)
    assert s.total_operand_bytes == 400
    assert s.total_wire_bytes == int(2 * 400 * 7 / 8)


def test_collective_stats_tuple_result_start():
    # async starts often carry tuple results (buffer pairs): both count
    hlo = ("%rs-start = (f32[64], f32[64]) reduce-scatter(%x), "
           "replica_groups={{0,1}}\n")
    s = collective_stats(hlo, n_devices=2)
    assert s.counts == {"reduce-scatter": 1}
    assert s.operand_bytes["reduce-scatter"] == 2 * 64 * 4


def test_collective_stats_ignores_non_collectives():
    hlo = ("%d = f32[8,8] dot(%a, %b)\n"
           "%t = f32[8,8] transpose(%d)\n")
    s = collective_stats(hlo, n_devices=4)
    assert s.counts == {}
    assert s.total_wire_bytes == 0
